//! Command-line interface of the `forest-add` binary.
//!
//! Subcommands:
//! - `datasets` — list built-in datasets
//! - `train`    — train a Random Forest and save it as JSON
//! - `compile`  — aggregate a forest into a decision diagram (+ DOT export,
//!   `--format fdd` for a binary snapshot)
//! - `freeze`   — render a compiled diagram into an `fdd-v2` snapshot
//! - `bundle`   — `pack` fdd snapshots into one `fab-v1` multi-model
//!   bundle / `ls` a bundle's manifest
//! - `inspect`  — show an `fdd` snapshot's (or `fab` bundle's) header,
//!   sections and stats
//! - `eval`     — steps/size/accuracy comparison table for one dataset
//! - `bench`    — deterministic batch-throughput baseline (rows/sec per
//!   backend × dataset × batch size, written to `BENCH_batch.json`)
//! - `serve`    — start the HTTP serving coordinator (`--snapshot` serves a
//!   pre-compiled artifact without training; `--io sync|evented` picks
//!   the socket front-end)
//! - `classify` — client convenience: send one request to a running server
//! - `models`   — client convenience: list models on a running server
//! - `loadgen`  — fire concurrent keep-alive traffic (JSON + binary row
//!   frames) at a running server, optionally asserting bit-identical
//!   responses against a reference server and nonzero latency quantiles
//! - `artifacts`— inspect compiled XLA artifact variants
//!
//! Every evaluation the CLI performs goes through [`Classifier`] trait
//! objects resolved from a [`ModelRegistry`] — the CLI never dispatches
//! on a concrete evaluator type.

use crate::batch::RowMatrixBuf;
use crate::bench_support::measure_ns;
use crate::classifier::{self, Classifier};
use crate::compile::{Abstraction, CompileOptions, CompiledDD, ForestCompiler};
use crate::data::datasets;
use crate::engine::ModelRegistry;
use crate::error::{Error, Result};
use crate::forest::{ForestLearner, RandomForest};
use crate::frozen::{self, FrozenDD};
use crate::net::proto;
use crate::predicate::PredicateOrder;
use crate::serve::config::{IoMode, ServeConfig};
use crate::serve::http::{http_request, HttpClient};
use crate::serve::{server, BackendKind};
use crate::util::argparse::{ArgSpec, Args};
use crate::util::json::{self, Json};
use crate::util::table::{fmt_thousands, Table};
use std::sync::Arc;
use std::time::Duration;

const USAGE: &str = "forest-add — Large Random Forests, optimised for rapid evaluation

USAGE:
  forest-add <COMMAND> [OPTIONS]

COMMANDS:
  datasets   List built-in datasets
  train      Train a Random Forest and save it (JSON)
  compile    Compile a forest into a decision diagram
  freeze     Freeze a compiled diagram into an fdd-v2 binary snapshot
  bundle     Pack fdd snapshots into a fab-v1 multi-model bundle / list one
  inspect    Inspect an fdd snapshot or fab bundle (header, sections, stats)
  eval       Compare RF vs DD steps/size/accuracy on a dataset
  bench      Batch-throughput baseline (writes BENCH_batch.json)
  serve      Start the HTTP serving coordinator
  classify   Send one classification request to a running server
  models     List the models registered on a running server
  loadgen    Fire concurrent keep-alive traffic at a running server
  artifacts  List compiled XLA artifact variants

Run `forest-add <COMMAND> --help` for per-command options.
";

/// CLI entrypoint.
pub fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    let rest = args[1..].to_vec();
    match cmd.as_str() {
        "datasets" => cmd_datasets(),
        "train" => cmd_train(&rest),
        "compile" => cmd_compile(&rest),
        "freeze" => cmd_freeze(&rest),
        "bundle" => cmd_bundle(&rest),
        "inspect" => cmd_inspect(&rest),
        "eval" => cmd_eval(&rest),
        "bench" => cmd_bench(&rest),
        "serve" => cmd_serve(&rest),
        "classify" => cmd_classify(&rest),
        "models" => cmd_models(&rest),
        "loadgen" => cmd_loadgen(&rest),
        "artifacts" => cmd_artifacts(&rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(Error::invalid(format!(
            "unknown command '{other}'\n\n{USAGE}"
        ))),
    }
}

fn cmd_datasets() -> Result<()> {
    let mut t = Table::new(&["name", "rows", "features", "classes", "class histogram"]);
    for name in datasets::names() {
        let ds = datasets::load(name)?;
        t.row(vec![
            name.to_string(),
            ds.n_rows().to_string(),
            ds.n_features().to_string(),
            ds.n_classes().to_string(),
            format!("{:?}", ds.class_histogram()),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn train_spec() -> ArgSpec {
    ArgSpec::new("forest-add train", "Train a Random Forest")
        .req("dataset", "built-in dataset name or .csv/.arff path")
        .opt("trees", "100", "number of trees")
        .opt("seed", "42", "training seed")
        .opt("max-depth", "0", "depth cap (0 = unlimited)")
        .opt(
            "task",
            "auto",
            "auto | classification | regression (assert the dataset's task)",
        )
        .opt("out", "model.json", "output model path")
}

fn cmd_train(args: &[String]) -> Result<()> {
    let a = train_spec().parse(args)?;
    let ds = crate::data::resolve(a.str("dataset"))?;
    // The dataset schema decides the task (a regression dataset carries a
    // per-bin value table); --task only asserts the expectation so a
    // pipeline script fails loudly on the wrong dataset spec.
    let is_reg = ds.schema.task.is_regression();
    match a.str("task") {
        "auto" => {}
        "classification" if !is_reg => {}
        "regression" if is_reg => {}
        "classification" | "regression" => {
            return Err(Error::invalid(format!(
                "--task {} but dataset '{}' is a {} dataset (try `forest-add datasets`)",
                a.str("task"),
                ds.name,
                if is_reg { "regression" } else { "classification" }
            )));
        }
        other => {
            return Err(Error::invalid(format!(
                "unknown task '{other}' (auto|classification|regression)"
            )));
        }
    }
    let forest = ForestLearner::default()
        .trees(a.usize("trees")?)
        .seed(a.u64("seed")?)
        .max_depth(a.usize("max-depth")?)
        .fit(&ds);
    let out = a.str("out");
    forest.save(out)?;
    println!(
        "trained {} trees on '{}' ({} nodes, train acc {:.4}) -> {out}",
        forest.n_trees(),
        ds.name,
        forest.n_nodes(),
        classifier::accuracy(&forest, &ds)?
    );
    if let Some(values) = ds.schema.values() {
        println!(
            "task: regression over {} target bins (values {:.3}..{:.3}); compile with \
             `--abstraction vector` to keep vote vectors for value prediction",
            values.len(),
            values.iter().cloned().fold(f32::INFINITY, f32::min),
            values.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
        );
    }
    Ok(())
}

fn compile_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add compile",
        "Compile a forest into a decision diagram",
    )
    .opt("model", "", "trained model JSON (from `train`)")
    .opt("dataset", "", "train in-place on this dataset instead")
    .opt("trees", "100", "trees when training in-place")
    .opt("seed", "42", "seed when training in-place")
    .opt("abstraction", "majority", "word | vector | majority")
    .switch("no-unsat", "disable unsatisfiable-path elimination")
    .opt("reduce-every", "1", "reduction cadence in trees (0 = end only)")
    .opt("order", "frequency", "predicate order: frequency | threshold")
    .opt("budget", "0", "live-node budget (0 = unlimited)")
    .opt("dot", "", "write Graphviz DOT of the final diagram")
    .opt("out", "", "save the compiled diagram (see --format)")
    .opt("format", "json", "output format for --out: json | fdd")
}

fn parse_abstraction(s: &str) -> Result<Abstraction> {
    match s {
        "word" => Ok(Abstraction::Word),
        "vector" => Ok(Abstraction::Vector),
        "majority" | "mv" => Ok(Abstraction::Majority),
        other => Err(Error::invalid(format!("unknown abstraction '{other}'"))),
    }
}

fn parse_order(s: &str) -> Result<PredicateOrder> {
    match s {
        "threshold" => Ok(PredicateOrder::FeatureThreshold),
        "frequency" => Ok(PredicateOrder::FrequencyDesc),
        other => Err(Error::invalid(format!("unknown order '{other}'"))),
    }
}

fn load_or_train(a: &Args) -> Result<(RandomForest, Option<crate::data::Dataset>)> {
    let model = a.str("model");
    if !model.is_empty() {
        return Ok((RandomForest::load(model)?, None));
    }
    let dataset = a.str("dataset");
    if dataset.is_empty() {
        return Err(Error::invalid("need --model or --dataset"));
    }
    let ds = crate::data::resolve(dataset)?;
    let forest = ForestLearner::default()
        .trees(a.usize("trees")?)
        .seed(a.u64("seed")?)
        .fit(&ds);
    Ok((forest, Some(ds)))
}

fn cmd_compile(args: &[String]) -> Result<()> {
    let a = compile_spec().parse(args)?;
    // Validate before the (potentially long) compile, and regardless of
    // whether --out was given.
    let format = a.str("format");
    if format != "json" && format != "fdd" {
        return Err(Error::invalid(format!("unknown format '{format}' (json|fdd)")));
    }
    let (forest, ds) = load_or_train(&a)?;
    let opts = CompileOptions {
        abstraction: parse_abstraction(a.str("abstraction"))?,
        unsat_elim: !a.flag("no-unsat"),
        reduce_every: a.usize("reduce-every")?,
        order: parse_order(a.str("order"))?,
        node_budget: a.usize("budget")?,
        ..Default::default()
    };
    let dd = ForestCompiler::new(opts).compile(&forest)?;
    let s = dd.size();
    println!(
        "{}: {} trees -> {} nodes ({} decision + {} terminal), {} predicates, {} reductions, {:.2?}",
        dd.label(),
        forest.n_trees(),
        s.total(),
        s.internal,
        s.terminals,
        dd.stats.predicates,
        dd.stats.reduces,
        dd.stats.elapsed
    );
    println!(
        "forest size {} nodes -> reduction {:.2}%",
        forest.n_nodes(),
        100.0 * (1.0 - s.total() as f64 / forest.n_nodes() as f64)
    );
    if let Some(ds) = &ds {
        // Both structures are measured through the Classifier trait — the
        // same dispatch path the serving router uses.
        let rf_steps = classifier::mean_steps(&forest, ds)?;
        let dd_steps = classifier::mean_steps(&dd, ds)?;
        println!(
            "mean steps: forest {} vs DD {} | agreement {:.4}",
            rf_steps
                .map(|s| fmt_thousands(s, 2))
                .unwrap_or_else(|| "—".into()),
            dd_steps
                .map(|s| fmt_thousands(s, 2))
                .unwrap_or_else(|| "—".into()),
            classifier::agreement(&forest, &dd, ds)?
        );
    }
    let dot = a.str("dot");
    if !dot.is_empty() {
        std::fs::write(dot, dd.to_dot())?;
        println!("wrote {dot}");
    }
    let out = a.str("out");
    if !out.is_empty() {
        if format == "fdd" {
            dd.freeze().save(out)?;
            let bytes = std::fs::metadata(out)?.len();
            println!(
                "wrote {out} ({bytes} bytes; serve with `forest-add serve --snapshot {out}`)"
            );
        } else {
            dd.save(out)?;
            println!("wrote {out} (load on replicas with CompiledDD::load)");
        }
    }
    Ok(())
}

fn freeze_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add freeze",
        "Freeze a compiled diagram into an fdd-v2 binary snapshot",
    )
    .opt("dd", "", "compiled diagram JSON (from `compile --out`)")
    .opt("model", "", "trained forest JSON (compiled first)")
    .opt("dataset", "", "train in-place on this dataset instead")
    .opt("trees", "100", "trees when training in-place")
    .opt("seed", "42", "seed when training in-place")
    .opt("abstraction", "majority", "word | vector | majority (ignored with --dd)")
    .switch("no-unsat", "disable unsatisfiable-path elimination")
    .switch(
        "quantize-f16",
        "quantise thresholds to f16 (halves the hot plane; fails if lossy)",
    )
    .switch(
        "pack-features",
        "reorder feature columns by test frequency for batch-gather locality",
    )
    .opt("out", "model.fdd", "output snapshot path")
}

fn cmd_freeze(args: &[String]) -> Result<()> {
    let a = freeze_spec().parse(args)?;
    let dd = if !a.str("dd").is_empty() {
        CompiledDD::load(a.str("dd"))?
    } else {
        let (forest, _) = load_or_train(&a)?;
        let opts = CompileOptions {
            abstraction: parse_abstraction(a.str("abstraction"))?,
            unsat_elim: !a.flag("no-unsat"),
            ..Default::default()
        };
        ForestCompiler::new(opts).compile(&forest)?
    };
    let frozen = dd.freeze_with(frozen::FreezeOpts {
        quantize_f16: a.flag("quantize-f16"),
        pack_features: a.flag("pack-features"),
    })?;
    let out = a.str("out");
    frozen.save(out)?;
    let s = frozen.size();
    let bytes = std::fs::metadata(out)?.len();
    println!(
        "froze {}: {} nodes ({} decision + {} terminal), {} predicates -> {out} ({bytes} bytes)",
        frozen.label(),
        s.total(),
        s.internal,
        s.terminals,
        frozen.n_preds()
    );
    if a.flag("quantize-f16") || a.flag("pack-features") {
        println!(
            "layout: {} thresholds, feature columns {}",
            if a.flag("quantize-f16") { "f16" } else { "f32" },
            if a.flag("pack-features") {
                "packed by frequency"
            } else {
                "in schema order"
            }
        );
    }
    println!("serve with `forest-add serve --snapshot {out}`");
    Ok(())
}

fn bundle_pack_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add bundle pack",
        "Pack fdd snapshots into one fab-v1 multi-model bundle",
    )
    .req(
        "entries",
        "comma-separated name[@shard][#version]=path.fdd specs (e.g. 'iris@shard-0#3=iris.fdd,lenses=lenses.fdd'; version defaults to 1)",
    )
    .opt("out", "fleet.fab", "output bundle path")
}

fn bundle_ls_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add bundle ls",
        "List the manifest of a fab-v1 bundle",
    )
    .req("bundle", "bundle path (from `bundle pack`)")
}

fn cmd_bundle(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("pack") => cmd_bundle_pack(&args[1..]),
        Some("ls") => cmd_bundle_ls(&args[1..]),
        _ => Err(Error::invalid(
            "usage: forest-add bundle <pack|ls> [OPTIONS] (try `bundle pack --help`)",
        )),
    }
}

/// Parse one `name[@shard][#version]=path` entry spec (version defaults
/// to 1 — the manifest's deploy-provenance stamp).
fn parse_entry_spec(spec: &str) -> Result<(String, String, u64, String)> {
    let bad = || Error::invalid(format!("bad entry spec '{spec}' (want name[@shard][#version]=path)"));
    let (id, path) = spec.split_once('=').ok_or_else(bad)?;
    let (id, version) = match id.split_once('#') {
        Some((i, v)) => (i, v.parse::<u64>().map_err(|_| bad())?),
        None => (id, 1),
    };
    let (name, shard) = match id.split_once('@') {
        Some((n, s)) => (n, s),
        None => (id, ""),
    };
    if name.is_empty() || path.is_empty() {
        return Err(bad());
    }
    Ok((name.to_string(), shard.to_string(), version, path.to_string()))
}

fn cmd_bundle_pack(args: &[String]) -> Result<()> {
    let a = bundle_pack_spec().parse(args)?;
    let mut entries: Vec<(String, u64, String, Vec<u8>)> = Vec::new();
    for spec in a.str("entries").split(',') {
        let (name, shard, version, path) = parse_entry_spec(spec.trim())?;
        let bytes = std::fs::read(&path)?;
        // Full structural validation before anything is packed: a bundle
        // member that cannot boot must fail the pipeline, not the fleet.
        FrozenDD::from_bytes(&bytes)
            .map_err(|e| Error::invalid(format!("entry '{name}' ({path}): {e}")))?;
        entries.push((name, version, shard, bytes));
    }
    let bytes = frozen::bundle::pack_snapshots(&entries)?;
    let out = a.str("out");
    frozen::bundle::save(out, &bytes)?;
    println!(
        "packed {} models into {out} ({} bytes)",
        entries.len(),
        bytes.len()
    );
    for (name, _, shard, data) in &entries {
        println!(
            "  {name}{} ({} bytes)",
            if shard.is_empty() {
                String::new()
            } else {
                format!(" @{shard}")
            },
            data.len()
        );
    }
    println!("serve with `forest-add serve --bundle {out}`");
    Ok(())
}

fn cmd_bundle_ls(args: &[String]) -> Result<()> {
    let a = bundle_ls_spec().parse(args)?;
    let bytes = std::fs::read(a.str("bundle"))?;
    print_bundle(&bytes)
}

/// Shared by `bundle ls` and `inspect` on a `fab` file.
fn print_bundle(bytes: &[u8]) -> Result<()> {
    let s = frozen::bundle::summarize(bytes)?;
    println!(
        "format: {}, {} bytes, checksum {:#018x} (verified), {} models",
        frozen::bundle::FORMAT_NAME,
        s.file_len,
        s.checksum,
        s.entries.len()
    );
    println!(
        "boot: {}",
        if crate::runtime::mmap::enabled() {
            "one mmap for the whole fleet (entries borrow the shared mapping)"
        } else {
            "buffered read (mmap unsupported or disabled on this host)"
        }
    );
    let mut t = Table::new(&["model", "version", "shard", "format", "offset", "bytes", "checksum"]);
    for e in &s.entries {
        let member = frozen::snapshot::summarize(&bytes[e.offset..e.offset + e.len])?;
        t.row(vec![
            e.name.clone(),
            format!("v{}", e.version),
            if e.shard.is_empty() { "—".into() } else { e.shard.clone() },
            format!("fdd-v{}", member.version),
            e.offset.to_string(),
            e.len.to_string(),
            format!("{:#018x}", e.checksum),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn inspect_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add inspect",
        "Inspect an fdd snapshot or fab bundle (header, sections, stats)",
    )
    .req("snapshot", "snapshot or bundle path (from `freeze` / `bundle pack`)")
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let a = inspect_spec().parse(args)?;
    let bytes = std::fs::read(a.str("snapshot"))?;
    if frozen::bundle::is_bundle(&bytes) {
        return print_bundle(&bytes);
    }
    let s = frozen::snapshot::summarize(&bytes)?;
    println!(
        "format: forest-add/fdd-v{}, {} bytes, checksum {:#018x} (verified)",
        s.version, s.file_len, s.checksum
    );
    // Full structural validation happens on load; reaching here with a
    // FrozenDD in hand proves the artifact is servable.
    let dd = FrozenDD::from_bytes(&bytes)?;
    println!(
        "{}: {} trees, {} features, {} classes, {} predicates",
        dd.label(),
        s.n_trees,
        s.n_features,
        s.n_classes,
        s.n_preds
    );
    println!(
        "diagram: {} decision nodes + {} terminals (root {})",
        s.n_nodes,
        s.n_terminals,
        if s.n_nodes == 0 { "terminal" } else { "node 0" }
    );
    // Memory footprint of the serving layout: hot bytes per decision and
    // the node-plane total, plus whether this host boots it zero-copy.
    // (A v1 artifact is upgraded on load, so its *runtime* hot record is
    // whatever the schema re-derives — report that, not the 16-byte AoS
    // layout the file was written for.)
    let nodes = s.n_nodes.max(1) as f64;
    let runtime_width = if s.version >= 2 {
        s.feat_width
    } else {
        dd.feat_width().bytes()
    };
    let thresh_bytes: u32 = if s.thresh_quant == frozen::ThreshQuant::F16 { 2 } else { 4 };
    println!(
        "encoding: {} features{}, {} B hot record at runtime, {:.1} B/node on disk ({} B node sections)",
        if runtime_width == 2 { "u16" } else { "u32" },
        if s.version >= 2 { "" } else { " after upgrade (v1 file stores u32)" },
        u32::from(runtime_width) + thresh_bytes,
        s.node_section_bytes() as f64 / nodes,
        s.node_section_bytes()
    );
    println!(
        "thresholds: {}",
        if s.thresh_quant == frozen::ThreshQuant::F16 {
            "f16 quantised (predicate table stores the widened values)"
        } else {
            "f32"
        }
    );
    // Payload semantics: what a terminal's vote vector is folded into.
    // The value table is authoritative in the loaded schema (section 12
    // bytes were validated on load), so report it from the classifier.
    if s.regression {
        let values = dd.task_values().unwrap_or_default();
        println!(
            "task: regression — {} target bins, values {:.3}..{:.3} (vote-weighted mean; section `values`)",
            values.len(),
            values.iter().cloned().fold(f32::INFINITY, f32::min),
            values.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
        );
    } else {
        println!("task: classification — argmax over terminal vote vectors");
    }
    println!(
        "feature columns: {}",
        if s.packed_features {
            "packed by test frequency (permutation applied on load)"
        } else {
            "schema order"
        }
    );
    println!(
        "boot: {}",
        if s.version >= 2 && crate::runtime::mmap::enabled() {
            "mmap zero-copy (sections back the runtime arrays in place)"
        } else if s.version >= 2 {
            "buffered read (mmap unsupported on this target)"
        } else {
            "upgrade-on-load (v1 artifact; re-save to write fdd-v2)"
        }
    );
    let mut t = Table::new(&["section", "offset", "bytes"]);
    for (name, offset, len) in &s.sections {
        t.row(vec![name.to_string(), offset.to_string(), len.to_string()]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn eval_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add eval",
        "Compare forest vs diagram variants on one dataset",
    )
    .req("dataset", "built-in dataset name or .csv/.arff path")
    .opt("trees", "100", "forest size")
    .opt("seed", "42", "training seed")
    .opt("budget", "2000000", "node budget for non-* variants")
}

fn cmd_eval(args: &[String]) -> Result<()> {
    let a = eval_spec().parse(args)?;
    let ds = crate::data::resolve(a.str("dataset"))?;
    let forest = ForestLearner::default()
        .trees(a.usize("trees")?)
        .seed(a.u64("seed")?)
        .fit(&ds);
    let schema = forest.schema.clone();
    // Every structure is registered as a named model and measured through
    // the Classifier trait object resolved from the registry — the exact
    // dispatch path the serving router uses.
    let registry = ModelRegistry::new();
    registry.register(
        "forest",
        schema.clone(),
        vec![(
            BackendKind::Forest,
            Arc::new(forest.clone()) as Arc<dyn Classifier>,
        )],
    )?;
    let mut names: Vec<&str> = vec!["forest"];
    let mut cutoffs: Vec<(String, String)> = Vec::new();
    for (name, abstraction) in [
        ("word-dd", Abstraction::Word),
        ("vector-dd", Abstraction::Vector),
        ("majority-dd", Abstraction::Majority),
    ] {
        let opts = CompileOptions {
            abstraction,
            unsat_elim: true,
            node_budget: a.usize("budget")?,
            ..Default::default()
        };
        match ForestCompiler::new(opts).compile(&forest) {
            Ok(dd) => {
                // The frozen form of the paper's headline variant rides
                // along so the table shows the serving layout too.
                if abstraction == Abstraction::Majority {
                    registry.register(
                        "frozen-dd",
                        schema.clone(),
                        vec![(
                            BackendKind::Frozen,
                            Arc::new(dd.freeze()) as Arc<dyn Classifier>,
                        )],
                    )?;
                }
                registry.register(
                    name,
                    schema.clone(),
                    vec![(BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>)],
                )?;
                names.push(name);
                if abstraction == Abstraction::Majority {
                    names.push("frozen-dd");
                }
            }
            Err(Error::Capacity(msg)) => cutoffs.push((abstraction.label(true), msg)),
            Err(e) => return Err(e),
        }
    }
    let mut t = Table::new(&["model", "structure", "mean steps", "size (nodes)", "accuracy"]);
    for name in names {
        let (_, slot) = registry.resolve(Some(name), None)?;
        let c = slot.classifier.as_ref();
        let info = c.info();
        let steps = classifier::mean_steps(c, &ds)?;
        t.row(vec![
            name.to_string(),
            info.label,
            steps
                .map(|s| fmt_thousands(s, 2))
                .unwrap_or_else(|| "—".into()),
            fmt_thousands(info.size_nodes as f64, 0),
            format!("{:.4}", classifier::accuracy(c, &ds)?),
        ]);
    }
    for (label, msg) in cutoffs {
        t.row(vec![
            "—".into(),
            format!("{label} (cut off)"),
            "—".into(),
            msg,
            "—".into(),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

fn bench_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add bench",
        "Deterministic batch-throughput baseline (rows/sec per backend × dataset × batch size)",
    )
    .opt("datasets", "iris,tic-tac-toe", "comma-separated dataset specs")
    .opt("trees", "64", "forest size")
    .opt("seed", "42", "training seed")
    .opt("batches", "64,256,1024,4096", "comma-separated batch sizes")
    .opt("secs", "0.2", "measurement window per cell in seconds")
    .opt(
        "json",
        "BENCH_batch.json",
        "write the JSON report here (empty = table only)",
    )
}

/// One measured bench cell: table row + JSON record.
fn bench_cell(
    t: &mut Table,
    results: &mut Vec<Json>,
    dataset: &str,
    backend: &str,
    batch: usize,
    ns_per_batch: f64,
) {
    let rows_per_sec = batch as f64 * 1e9 / ns_per_batch;
    t.row(vec![
        dataset.to_string(),
        backend.to_string(),
        batch.to_string(),
        fmt_thousands(rows_per_sec, 0),
    ]);
    results.push(json::obj(vec![
        ("dataset", json::s(dataset)),
        ("backend", json::s(backend)),
        ("batch", json::num(batch as f64)),
        ("rows_per_sec", json::num(rows_per_sec)),
    ]));
}

/// The perf-trajectory baseline: a fixed workload (dataset × backend ×
/// batch size, seeds pinned) measured through the same entry points the
/// serving path uses, dumped as `BENCH_batch.json` so successive PRs can
/// be compared. `frozen-1t` is the single-threaded scratch sweep — the
/// gap to `frozen` is the multi-core sharding win. `frozen-scalar` vs
/// `frozen-simd` pin the kernel explicitly on the same sweep — the gap
/// is the lane win on this host (identical on machines with no SIMD).
/// `frozen-f16` runs the quantised + column-packed freeze.
fn cmd_bench(args: &[String]) -> Result<()> {
    let a = bench_spec().parse(args)?;
    let window = Duration::from_secs_f64(a.f64("secs")?);
    let trees = a.usize("trees")?;
    let seed = a.u64("seed")?;
    let batches: Vec<usize> = a
        .str("batches")
        .split(',')
        .map(|b| {
            b.trim()
                .parse::<usize>()
                .ok()
                .filter(|&n| n > 0)
                .ok_or_else(|| Error::invalid(format!("bad batch size '{b}'")))
        })
        .collect::<Result<_>>()?;
    let mut t = Table::new(&["dataset", "backend", "batch", "rows/s"]);
    let mut results: Vec<Json> = Vec::new();
    for spec in a.str("datasets").split(',') {
        let spec = spec.trim();
        let ds = crate::data::resolve(spec)?;
        let forest = ForestLearner::default().trees(trees).seed(seed).fit(&ds);
        let dd = ForestCompiler::new(CompileOptions::default()).compile(&forest)?;
        let frozen_dd = dd.freeze();
        // The optimised freeze can legitimately refuse a dataset (f16
        // range / per-feature collisions) — report and skip the series
        // rather than failing the whole baseline.
        let frozen_f16 = match dd.freeze_with(frozen::FreezeOpts {
            quantize_f16: true,
            pack_features: true,
        }) {
            Ok(q) => Some(q),
            Err(e) => {
                eprintln!("bench: skipping frozen-f16 for '{spec}': {e}");
                None
            }
        };
        let kernel = crate::runtime::simd::kernel();
        for &batch in &batches {
            let buf = crate::bench_support::tile_rows(&ds, batch, 1);
            let rows = buf.as_matrix();
            let ns = measure_ns(window, || {
                std::hint::black_box(forest.predict_batch(rows).len());
            });
            bench_cell(&mut t, &mut results, spec, "forest", batch, ns);
            let ns = measure_ns(window, || {
                let out = Classifier::classify_batch(&dd, rows).expect("dd batch");
                std::hint::black_box(out.len());
            });
            bench_cell(&mut t, &mut results, spec, "dd", batch, ns);
            let ns = measure_ns(window, || {
                std::hint::black_box(frozen_dd.classify_batch(rows).len());
            });
            bench_cell(&mut t, &mut results, spec, "frozen", batch, ns);
            let mut scratch = frozen::BatchScratch::new();
            let mut out = Vec::new();
            let ns = measure_ns(window, || {
                frozen_dd.classify_batch_into(rows, &mut scratch, &mut out);
                std::hint::black_box(out.len());
            });
            bench_cell(&mut t, &mut results, spec, "frozen-1t", batch, ns);
            // the cache-tiled chain sweep forced via a budget of 1
            // (= minimum-size tiles) — on diagrams that fit the LLC this
            // reads as tiling overhead vs frozen-1t, on larger ones as
            // the benefit; larger budgets would silently fall back to
            // the rounds sweep and re-measure frozen-1t under a new name
            let ns = measure_ns(window, || {
                frozen_dd.classify_batch_into_tiled(rows, &mut scratch, &mut out, 1);
                std::hint::black_box(out.len());
            });
            bench_cell(&mut t, &mut results, spec, "frozen-tiled", batch, ns);
            // kernel-pinned pair: same single-threaded rounds sweep,
            // scalar walk vs the best kernel this host detects
            let ns = measure_ns(window, || {
                frozen_dd.classify_batch_kernel_into(
                    rows,
                    &mut scratch,
                    &mut out,
                    0,
                    crate::runtime::simd::Kernel::Scalar,
                );
                std::hint::black_box(out.len());
            });
            bench_cell(&mut t, &mut results, spec, "frozen-scalar", batch, ns);
            let ns = measure_ns(window, || {
                frozen_dd.classify_batch_kernel_into(rows, &mut scratch, &mut out, 0, kernel);
                std::hint::black_box(out.len());
            });
            bench_cell(&mut t, &mut results, spec, "frozen-simd", batch, ns);
            if let Some(q) = &frozen_f16 {
                let ns = measure_ns(window, || {
                    q.classify_batch_into(rows, &mut scratch, &mut out);
                    std::hint::black_box(out.len());
                });
                bench_cell(&mut t, &mut results, spec, "frozen-f16", batch, ns);
            }
        }
    }
    print!("{}", t.to_text());
    let report = json::obj(vec![
        ("bench", json::s("batch_throughput")),
        ("trees", json::num(trees as f64)),
        ("seed", json::num(seed as f64)),
        (
            "eval_threads",
            json::num(crate::runtime::pool::eval_threads() as f64),
        ),
        ("window_secs", json::num(a.f64("secs")?)),
        ("results", Json::Arr(results)),
    ]);
    let out_path = a.str("json");
    if !out_path.is_empty() {
        std::fs::write(out_path, report.to_string_pretty())?;
        println!("wrote {out_path}");
    }
    Ok(())
}

fn serve_spec() -> ArgSpec {
    ArgSpec::new("forest-add serve", "Start the HTTP serving coordinator")
        .opt("config", "", "JSON config file (CLI flags override)")
        .opt("addr", "", "bind address, e.g. 127.0.0.1:7878")
        .opt("snapshot", "", "serve this fdd snapshot (skips training)")
        .opt("bundle", "", "serve this fab-v1 multi-model bundle (skips training)")
        .opt("dataset", "", "dataset to train on")
        .opt("trees", "", "forest size")
        .opt("max-depth", "", "tree depth cap")
        .opt("backend", "", "default backend: forest | dd | frozen | xla")
        .opt("artifacts", "", "artifacts directory")
        .opt("variant", "", "artifact variant (small | base | wide)")
        .opt("reply-timeout-ms", "", "batched-reply timeout in milliseconds")
        .opt("http-workers", "", "HTTP worker threads")
        .opt("io", "", "socket front-end: auto | sync | evented")
        .opt(
            "read-timeout-ms",
            "",
            "per-connection read/idle timeout in milliseconds",
        )
        .opt("eval-threads", "", "evaluation parallelism (0 = all cores)")
        .opt("tile-bytes", "", "frozen sweep LLC tile budget in bytes (0 = auto)")
        .opt(
            "class-weights",
            "",
            "comma-separated per-class decision weights (weighted argmax)",
        )
        .switch("no-simd", "force the scalar frozen sweep (FOREST_ADD_NO_SIMD=1 also wins)")
        .opt(
            "conn-max-inflight",
            "",
            "per-connection pipelining cap before 429 (0 = unlimited)",
        )
        .opt(
            "breaker-threshold",
            "",
            "eval failures in 10s that open a backend breaker (0 = off)",
        )
        .opt(
            "fault",
            "",
            "deterministic fault injection, point:rate:seed[,…]",
        )
        .opt(
            "log-level",
            "",
            "log verbosity: error | warn | info | debug | trace",
        )
        .switch("log-json", "emit logs as JSON lines on stderr")
        .switch("no-xla", "do not load the XLA backend")
        .switch("dump-config", "print the effective config and exit")
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let a = serve_spec().parse(args)?;
    let mut cfg = if a.str("config").is_empty() {
        ServeConfig::default()
    } else {
        ServeConfig::load(a.str("config"))?
    };
    if !a.str("addr").is_empty() {
        cfg.addr = a.str("addr").to_string();
    }
    if !a.str("snapshot").is_empty() {
        cfg.snapshot = a.str("snapshot").to_string();
    }
    if !a.str("bundle").is_empty() {
        cfg.bundle = a.str("bundle").to_string();
    }
    if !a.str("dataset").is_empty() {
        cfg.dataset = a.str("dataset").to_string();
    }
    if !a.str("trees").is_empty() {
        cfg.trees = a.usize("trees")?;
    }
    if !a.str("max-depth").is_empty() {
        cfg.max_depth = a.usize("max-depth")?;
    }
    if !a.str("backend").is_empty() {
        cfg.default_backend = BackendKind::parse(a.str("backend"))?;
    }
    if !a.str("artifacts").is_empty() {
        cfg.artifacts_dir = a.str("artifacts").to_string();
    }
    if !a.str("variant").is_empty() {
        cfg.variant = a.str("variant").to_string();
    }
    if !a.str("reply-timeout-ms").is_empty() {
        cfg.reply_timeout_ms = a.u64("reply-timeout-ms")?;
    }
    if !a.str("http-workers").is_empty() {
        cfg.http_workers = a.usize("http-workers")?;
    }
    if !a.str("io").is_empty() {
        cfg.io_mode = IoMode::parse(a.str("io"))?;
    }
    if !a.str("read-timeout-ms").is_empty() {
        cfg.read_timeout_ms = a.u64("read-timeout-ms")?;
    }
    if !a.str("eval-threads").is_empty() {
        cfg.eval_threads = a.usize("eval-threads")?;
    }
    if !a.str("tile-bytes").is_empty() {
        cfg.tile_bytes = a.usize("tile-bytes")?;
    }
    if !a.str("class-weights").is_empty() {
        cfg.class_weights = a
            .str("class-weights")
            .split(',')
            .map(|w| {
                w.trim()
                    .parse::<f32>()
                    .map_err(|_| Error::invalid(format!("bad class weight '{w}'")))
            })
            .collect::<Result<_>>()?;
    }
    if a.flag("no-simd") {
        cfg.simd = false;
    }
    if !a.str("conn-max-inflight").is_empty() {
        cfg.conn_max_inflight = a.usize("conn-max-inflight")?;
    }
    if !a.str("breaker-threshold").is_empty() {
        cfg.breaker_threshold = a.usize("breaker-threshold")?;
    }
    if !a.str("fault").is_empty() {
        cfg.fault = a.str("fault").to_string();
    }
    if !a.str("log-level").is_empty() {
        cfg.log_level = a.str("log-level").to_string();
    }
    if a.flag("log-json") {
        cfg.log_json = true;
    }
    if a.flag("no-xla") {
        cfg.enable_xla = false;
    }
    if a.flag("dump-config") {
        print!("{}", cfg.to_json().to_string_pretty());
        return Ok(());
    }
    let handle = server::start(&cfg)?;
    println!("serving on http://{} — Ctrl-C to stop", handle.addr);
    // Block forever; the process exits on signal.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn classify_spec() -> ArgSpec {
    ArgSpec::new("forest-add classify", "Classify one row via a running server")
        .req("addr", "server address, e.g. 127.0.0.1:7878")
        .req("features", "comma-separated feature values")
        .opt("backend", "", "forest | dd | frozen | xla")
        .opt("model", "", "named model (server default otherwise)")
        .switch("probs", "request the per-class vote distribution too")
}

fn cmd_classify(args: &[String]) -> Result<()> {
    let a = classify_spec().parse(args)?;
    let features: Vec<Json> = a
        .str("features")
        .split(',')
        .map(|v| {
            v.trim()
                .parse::<f64>()
                .map(json::num)
                .map_err(|_| Error::invalid(format!("bad feature value '{v}'")))
        })
        .collect::<Result<_>>()?;
    let mut fields = vec![("features", Json::Arr(features))];
    if !a.str("backend").is_empty() {
        fields.push(("backend", json::s(a.str("backend"))));
    }
    if !a.str("model").is_empty() {
        fields.push(("model", json::s(a.str("model"))));
    }
    if a.flag("probs") {
        fields.push(("probs", Json::Bool(true)));
    }
    let body = json::obj(fields);
    let (status, resp) = http_request(a.str("addr"), "POST", "/classify", Some(&body))?;
    println!("{}", resp.to_string_pretty());
    if status != 200 {
        return Err(Error::Serve(format!("server returned {status}")));
    }
    Ok(())
}

fn models_spec() -> ArgSpec {
    ArgSpec::new("forest-add models", "List models on a running server")
        .req("addr", "server address, e.g. 127.0.0.1:7878")
}

fn cmd_models(args: &[String]) -> Result<()> {
    let a = models_spec().parse(args)?;
    let (status, resp) = http_request(a.str("addr"), "GET", "/models", None)?;
    println!("{}", resp.to_string_pretty());
    if status != 200 {
        return Err(Error::Serve(format!("server returned {status}")));
    }
    Ok(())
}

fn loadgen_spec() -> ArgSpec {
    ArgSpec::new(
        "forest-add loadgen",
        "Fire concurrent keep-alive traffic at a running server",
    )
    .req("addr", "target server address, e.g. 127.0.0.1:7878")
    .opt(
        "reference",
        "",
        "second server; assert bit-identical responses (latency field aside)",
    )
    .opt("dataset", "iris", "dataset supplying the feature rows")
    .opt("conns", "64", "concurrent keep-alive connections")
    .opt("requests", "8", "requests per connection (cycles JSON/binary, single/batch)")
}

/// A dataset row as a JSON array of numbers.
fn loadgen_row_json(data: &crate::data::Dataset, r: usize) -> Json {
    Json::Arr(data.row(r).iter().map(|&v| json::num(v as f64)).collect())
}

/// One of the four request shapes loadgen cycles through: JSON single,
/// binary single, JSON batch, binary batch (with §6 steps).
fn loadgen_request(
    data: &crate::data::Dataset,
    conn: usize,
    seq: usize,
) -> Result<(String, &'static str, Vec<u8>)> {
    let n = data.n_rows();
    let i = (conn * 31 + seq * 7) % n;
    let j = (i + 1) % n;
    Ok(match seq % 4 {
        0 => (
            "/classify".to_string(),
            "application/json",
            json::obj(vec![("features", loadgen_row_json(data, i))])
                .to_string_compact()
                .into_bytes(),
        ),
        1 => {
            let mut buf = RowMatrixBuf::with_capacity(data.n_features(), 1);
            buf.push_row(data.row(i))?;
            (
                "/classify".to_string(),
                proto::BINARY_ROWS,
                proto::encode_rows(buf.as_matrix())?,
            )
        }
        2 => {
            let rows = Json::Arr(vec![
                loadgen_row_json(data, i),
                loadgen_row_json(data, j),
            ]);
            (
                "/classify_batch".to_string(),
                "application/json",
                json::obj(vec![("rows", rows)])
                    .to_string_compact()
                    .into_bytes(),
            )
        }
        _ => {
            let mut buf = RowMatrixBuf::with_capacity(data.n_features(), 2);
            buf.push_row(data.row(i))?;
            buf.push_row(data.row(j))?;
            (
                "/classify_batch?steps=true".to_string(),
                proto::BINARY_ROWS,
                proto::encode_rows(buf.as_matrix())?,
            )
        }
    })
}

/// True when two response payloads agree once the per-request
/// `latency_us` field is stripped.
fn payloads_match(a: &[u8], b: &[u8]) -> Result<bool> {
    let pa = Json::parse(&String::from_utf8_lossy(a))?;
    let pb = Json::parse(&String::from_utf8_lossy(b))?;
    Ok(json::strip_key(&pa, "latency_us") == json::strip_key(&pb, "latency_us"))
}

fn cmd_loadgen(args: &[String]) -> Result<()> {
    let a = loadgen_spec().parse(args)?;
    let addr = a.str("addr").to_string();
    let reference = a.str("reference").to_string();
    let conns = a.usize("conns")?;
    let requests = a.usize("requests")?;
    if conns == 0 || requests == 0 {
        return Err(Error::invalid("conns and requests must be positive"));
    }
    let data = Arc::new(crate::data::resolve(a.str("dataset"))?);
    let t0 = std::time::Instant::now();
    let mut workers = Vec::with_capacity(conns);
    for c in 0..conns {
        let addr = addr.clone();
        let reference = reference.clone();
        let data = data.clone();
        workers.push(std::thread::spawn(move || -> Result<()> {
            let mut target = HttpClient::connect(&addr)?;
            let mut twin = if reference.is_empty() {
                None
            } else {
                Some(HttpClient::connect(&reference)?)
            };
            for r in 0..requests {
                let (path, content_type, body) = loadgen_request(&data, c, r)?;
                let (status, _, payload) =
                    target.request_raw("POST", &path, content_type, &body)?;
                if status != 200 {
                    return Err(Error::Serve(format!(
                        "conn {c} req {r}: {path} returned {status}: {}",
                        String::from_utf8_lossy(&payload)
                    )));
                }
                if let Some(twin) = twin.as_mut() {
                    let (twin_status, _, twin_payload) =
                        twin.request_raw("POST", &path, content_type, &body)?;
                    if twin_status != status || !payloads_match(&payload, &twin_payload)? {
                        return Err(Error::Serve(format!(
                            "conn {c} req {r}: {path} diverged between servers:\n  target:    {}\n  reference: {}",
                            String::from_utf8_lossy(&payload),
                            String::from_utf8_lossy(&twin_payload)
                        )));
                    }
                }
            }
            Ok(())
        }));
    }
    let mut failures = Vec::new();
    for w in workers {
        match w.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => failures.push(e.to_string()),
            Err(_) => failures.push("loadgen worker panicked".into()),
        }
    }
    let elapsed = t0.elapsed();
    let total = conns * requests;
    if !failures.is_empty() {
        return Err(Error::Serve(format!(
            "{} of {conns} connections failed; first failure: {}",
            failures.len(),
            failures[0]
        )));
    }
    // the target must have measured every request we just sent
    let (status, metrics) = http_request(&addr, "GET", "/metrics", None)?;
    if status != 200 {
        return Err(Error::Serve(format!("/metrics returned {status}")));
    }
    let req_us = metrics
        .get("request_us")
        .ok_or_else(|| Error::Serve("/metrics lacks request_us".into()))?;
    let count = req_us.get_i64("count").unwrap_or(0);
    if count < total as i64 {
        return Err(Error::Serve(format!(
            "request_us.count = {count}, expected at least {total}"
        )));
    }
    for q in ["p50_us", "p95_us", "p99_us"] {
        if req_us.get_i64(q).unwrap_or(0) <= 0 {
            return Err(Error::Serve(format!(
                "request_us.{q} is zero after {total} requests"
            )));
        }
    }
    println!(
        "loadgen: {total} requests over {conns} keep-alive connections in {:.2}s ({:.0} req/s)",
        elapsed.as_secs_f64(),
        total as f64 / elapsed.as_secs_f64().max(1e-9),
    );
    println!(
        "loadgen: server io_mode {}, request latency p50 {} µs, p95 {} µs, p99 {} µs{}",
        metrics.get_str("io_mode").unwrap_or("?"),
        req_us.get_i64("p50_us").unwrap_or(0),
        req_us.get_i64("p95_us").unwrap_or(0),
        req_us.get_i64("p99_us").unwrap_or(0),
        if reference.is_empty() {
            ""
        } else {
            " — responses bit-identical to the reference server"
        }
    );
    Ok(())
}

fn artifacts_spec() -> ArgSpec {
    ArgSpec::new("forest-add artifacts", "List compiled XLA artifact variants")
        .opt("dir", "artifacts", "artifacts directory")
}

fn cmd_artifacts(args: &[String]) -> Result<()> {
    let a = artifacts_spec().parse(args)?;
    let dir = a.str("dir");
    let mut t = Table::new(&["variant", "batch", "trees", "depth", "features", "classes"]);
    for name in crate::runtime::VariantMeta::available(dir)? {
        let m = crate::runtime::VariantMeta::load(dir, &name)?;
        t.row(vec![
            m.name,
            m.batch.to_string(),
            m.trees.to_string(),
            m.depth.to_string(),
            m.features.to_string(),
            m.classes.to_string(),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_on_no_args_and_help() {
        run(vec![]).unwrap();
        run(vec!["help".into()]).unwrap();
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(run(vec!["frobnicate".into()]).is_err());
    }

    #[test]
    fn datasets_lists() {
        cmd_datasets().unwrap();
    }

    #[test]
    fn train_compile_eval_roundtrip() {
        let dir = std::env::temp_dir().join("forest-add-cli-test");
        std::fs::create_dir_all(&dir).unwrap();
        let model = dir.join("model.json");
        let model_s = model.to_str().unwrap().to_string();
        cmd_train(&[
            "--dataset".into(),
            "lenses".into(),
            "--trees".into(),
            "8".into(),
            "--out".into(),
            model_s.clone(),
        ])
        .unwrap();
        assert!(model.exists());
        let dot = dir.join("dd.dot");
        cmd_compile(&[
            "--model".into(),
            model_s,
            "--dot".into(),
            dot.to_str().unwrap().into(),
        ])
        .unwrap();
        let dot_text = std::fs::read_to_string(&dot).unwrap();
        assert!(dot_text.starts_with("digraph"));
        cmd_eval(&[
            "--dataset".into(),
            "lenses".into(),
            "--trees".into(),
            "10".into(),
        ])
        .unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn freeze_inspect_and_snapshot_compile_roundtrip() {
        let dir = std::env::temp_dir().join("forest-add-cli-freeze-test");
        std::fs::create_dir_all(&dir).unwrap();
        let snap = dir.join("lenses.fdd");
        let snap_s = snap.to_str().unwrap().to_string();
        cmd_freeze(&[
            "--dataset".into(),
            "lenses".into(),
            "--trees".into(),
            "7".into(),
            "--out".into(),
            snap_s.clone(),
        ])
        .unwrap();
        assert!(snap.exists());
        cmd_inspect(&["--snapshot".into(), snap_s.clone()]).unwrap();
        // compile --format fdd writes a loadable snapshot too
        let snap2 = dir.join("lenses2.fdd");
        cmd_compile(&[
            "--dataset".into(),
            "lenses".into(),
            "--trees".into(),
            "7".into(),
            "--format".into(),
            "fdd".into(),
            "--out".into(),
            snap2.to_str().unwrap().into(),
        ])
        .unwrap();
        let a = FrozenDD::load(&snap_s).unwrap();
        let b = FrozenDD::load(snap2.to_str().unwrap()).unwrap();
        assert_eq!(a.to_bytes(), b.to_bytes(), "same forest, same snapshot");
        // unknown formats are rejected
        assert!(cmd_compile(&[
            "--dataset".into(),
            "lenses".into(),
            "--trees".into(),
            "3".into(),
            "--format".into(),
            "cbor".into(),
            "--out".into(),
            dir.join("x").to_str().unwrap().into(),
        ])
        .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn freeze_quantized_packed_inspect_roundtrip() {
        let dir = std::env::temp_dir().join("forest-add-cli-freeze-q-test");
        std::fs::create_dir_all(&dir).unwrap();
        let plain = dir.join("plain.fdd");
        let opt = dir.join("opt.fdd");
        for (path, extra) in [(&plain, &[][..]), (&opt, &["--quantize-f16", "--pack-features"][..])]
        {
            let mut args = vec![
                "--dataset".to_string(),
                "lenses".into(),
                "--trees".into(),
                "7".into(),
                "--out".into(),
                path.to_str().unwrap().into(),
            ];
            args.extend(extra.iter().map(|s| s.to_string()));
            cmd_freeze(&args).unwrap();
        }
        // inspect reports the new layout lines without erroring
        cmd_inspect(&["--snapshot".into(), opt.to_str().unwrap().into()]).unwrap();
        let a = FrozenDD::load(plain.to_str().unwrap()).unwrap();
        let b = FrozenDD::load(opt.to_str().unwrap()).unwrap();
        assert_eq!(b.thresh_quant(), frozen::ThreshQuant::F16);
        assert!(b.packed_features());
        // the optimised layout is an encoding change only — predictions
        // over the whole dataset stay bit-identical
        let ds = crate::data::resolve("lenses").unwrap();
        let rows = ds.matrix();
        assert_eq!(a.classify_batch(rows), b.classify_batch(rows));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bundle_pack_ls_and_inspect_roundtrip() {
        let dir = std::env::temp_dir().join("forest-add-cli-bundle-test");
        std::fs::create_dir_all(&dir).unwrap();
        let a = dir.join("a.fdd");
        let b = dir.join("b.fdd");
        for (path, trees) in [(&a, 5usize), (&b, 9)] {
            cmd_freeze(&[
                "--dataset".into(),
                "lenses".into(),
                "--trees".into(),
                trees.to_string(),
                "--out".into(),
                path.to_str().unwrap().into(),
            ])
            .unwrap();
        }
        let fab = dir.join("fleet.fab");
        let fab_s = fab.to_str().unwrap().to_string();
        cmd_bundle(&[
            "pack".into(),
            "--entries".into(),
            format!(
                "alpha@shard-0#7={},beta={}",
                a.to_str().unwrap(),
                b.to_str().unwrap()
            ),
            "--out".into(),
            fab_s.clone(),
        ])
        .unwrap();
        assert!(fab.exists());
        cmd_bundle(&["ls".into(), "--bundle".into(), fab_s.clone()]).unwrap();
        // inspect dispatches on the fab magic
        cmd_inspect(&["--snapshot".into(), fab_s.clone()]).unwrap();
        // the packed bundle loads and boots
        let bundle = frozen::bundle::Bundle::load(&fab_s).unwrap();
        assert_eq!(bundle.entries()[0].name, "alpha");
        assert_eq!(bundle.entries()[0].shard, "shard-0");
        assert_eq!(bundle.entries()[0].version, 7, "#version spec lands in the manifest");
        assert_eq!(bundle.entries()[1].name, "beta");
        assert_eq!(bundle.entries()[1].shard, "");
        assert_eq!(bundle.entries()[1].version, 1, "version defaults to 1");
        bundle.boot(0).unwrap();
        bundle.boot(1).unwrap();
        // bad specs and subcommands are rejected
        assert!(cmd_bundle(&[
            "pack".into(),
            "--entries".into(),
            "no-equals-sign".into()
        ])
        .is_err());
        assert!(parse_entry_spec("m#x=path.fdd").is_err(), "non-numeric version");
        assert!(cmd_bundle(&["frobnicate".into()]).is_err());
        assert!(cmd_bundle(&[]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_writes_the_baseline_json() {
        let dir = std::env::temp_dir().join("forest-add-cli-bench-test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_batch.json");
        cmd_bench(&[
            "--datasets".into(),
            "lenses".into(),
            "--trees".into(),
            "5".into(),
            "--batches".into(),
            "8,32".into(),
            "--secs".into(),
            "0.01".into(),
            "--json".into(),
            out.to_str().unwrap().into(),
        ])
        .unwrap();
        let report = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(report.get_str("bench"), Some("batch_throughput"));
        let results = report.get("results").and_then(Json::as_arr).unwrap();
        // 1 dataset × 8 series × 2 batch sizes (lenses quantises cleanly,
        // so the frozen-f16 series is present)
        assert_eq!(results.len(), 16);
        for r in results {
            assert!(r.get("rows_per_sec").and_then(Json::as_f64).unwrap() > 0.0);
        }
        // bad batch sizes are rejected up front
        assert!(cmd_bench(&["--batches".into(), "0".into()]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parse_helpers() {
        assert_eq!(parse_abstraction("word").unwrap(), Abstraction::Word);
        assert!(parse_abstraction("x").is_err());
        assert_eq!(
            parse_order("frequency").unwrap(),
            PredicateOrder::FrequencyDesc
        );
        assert!(parse_order("x").is_err());
    }
}
