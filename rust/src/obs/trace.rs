//! Per-request trace contexts and the lock-free last-N trace ring.
//!
//! Every request carries a 64-bit id — parsed from the client's
//! `X-Request-Id` header when present, generated otherwise — and a
//! [`ReqTrace`] recording monotonic per-stage spans (`parse`,
//! `admission`, `queue`, `eval`, `serialize`, `write`) plus sampled
//! per-shard evaluation timings. Recording is allocation-free: spans
//! land in fixed arrays inside the trace, and [`ReqTrace::commit`]
//! publishes the finished trace into a static ring of atomics guarded
//! by per-slot sequence counters. Readers (`GET /debug/trace?n=`) walk
//! the ring backwards and drop any slot whose sequence moved mid-read —
//! debug-grade best effort that never blocks a writer. Two writers
//! landing on the same slot (256 commits apart) can interleave; the
//! parity check makes such a slot unreadable rather than torn.
//!
//! The module also owns the global per-shard timing table fed by
//! [`crate::runtime::pool`]: aggregate count/sum/max per shard index
//! (rendered by `/metrics`) and a best-effort sample of the most recent
//! sharded run (attached to inline `"trace": true` breakdowns).

use crate::util::json::{self, Json};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Sequential stages of one request's life, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// HTTP head + body parsing (the parser call that completed the
    /// request; socket wait time is excluded via [`ReqTrace::mark`]).
    Parse = 0,
    /// Admission control: dispatch-queue reservation (evented front-end;
    /// zero on the sync path, which admits by accepting the connection).
    Admission = 1,
    /// Waiting in the dispatch queue for a worker (evented front-end).
    Queue = 2,
    /// Model evaluation through the router.
    Eval = 3,
    /// Response body construction.
    Serialize = 4,
    /// Socket write, recorded when the response finishes flushing.
    Write = 5,
}

/// Number of sequential stages a trace records.
pub const N_STAGES: usize = 6;

/// Stage names, indexed by `Stage as usize`.
pub const STAGE_NAMES: [&str; N_STAGES] =
    ["parse", "admission", "queue", "eval", "serialize", "write"];

/// Per-shard samples a single trace can carry.
pub const MAX_TRACE_SHARDS: usize = 16;

/// Shard indexes the global timing table tracks.
pub const MAX_SHARD_STATS: usize = 32;

impl Stage {
    /// The stage's wire name (`"parse"`, …).
    pub fn name(self) -> &'static str {
        STAGE_NAMES[self as usize]
    }
}

/// One request's trace context: id, span cursor, and recorded stages.
///
/// The clock starts at `t0` (the moment the completing parse call
/// began), every [`record`](ReqTrace::record) attributes the time since
/// the previous record/mark to one stage, and
/// [`commit`](ReqTrace::commit) measures the end-to-end total from the
/// same `t0` — so the sum of the recorded stage spans can never exceed
/// the committed total.
#[derive(Debug, Clone)]
pub struct ReqTrace {
    /// 64-bit trace id (from `X-Request-Id` or [`next_id`]).
    pub id: u64,
    /// The client asked for the inline breakdown (`"trace": true`).
    pub inline: bool,
    /// Set by the endpoint layer when a circuit breaker rerouted the
    /// request: the backend that actually served it, echoed on the wire
    /// as `X-Served-By`.
    pub served_by: Option<&'static str>,
    t0: Instant,
    last: Instant,
    deadline: Option<Instant>,
    stage_us: [u64; N_STAGES],
    shard_us: [u64; MAX_TRACE_SHARDS],
    n_shards: usize,
}

impl ReqTrace {
    /// A trace whose clock starts now.
    pub fn new(id: u64) -> ReqTrace {
        ReqTrace::new_at(id, Instant::now())
    }

    /// A trace whose clock started at `t0`.
    pub fn new_at(id: u64, t0: Instant) -> ReqTrace {
        ReqTrace {
            id,
            inline: false,
            served_by: None,
            t0,
            last: t0,
            deadline: None,
            stage_us: [0; N_STAGES],
            shard_us: [0; MAX_TRACE_SHARDS],
            n_shards: 0,
        }
    }

    /// Reset the span cursor without attributing the elapsed gap to any
    /// stage (idle keep-alive time between pipelined requests).
    pub fn mark(&mut self) {
        self.last = Instant::now();
    }

    /// Attach the request's evaluation deadline (admission sets it from
    /// `ServeConfig::reply_timeout_ms`, capped lower by a client
    /// `X-Deadline-Ms` header). Rides the trace through the batcher and
    /// dispatch queues so every later stage can drop expired work.
    pub fn set_deadline(&mut self, deadline: Instant) {
        self.deadline = Some(deadline);
    }

    /// The request's deadline, if one was set.
    pub fn deadline(&self) -> Option<Instant> {
        self.deadline
    }

    /// True when the deadline has passed. Never true for deadline-less
    /// traces. Allocation-free (one clock read).
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Attribute the time since the last record/mark to `stage`.
    pub fn record(&mut self, stage: Stage) {
        let now = Instant::now();
        self.stage_us[stage as usize] += (now - self.last).as_micros() as u64;
        self.last = now;
    }

    /// Attach per-shard evaluation timings sampled from the pool
    /// (truncated to [`MAX_TRACE_SHARDS`]).
    pub fn set_shards(&mut self, us: &[u64]) {
        let n = us.len().min(MAX_TRACE_SHARDS);
        self.shard_us[..n].copy_from_slice(&us[..n]);
        self.n_shards = n;
    }

    /// Microseconds recorded for `stage` so far.
    pub fn stage_us(&self, stage: Stage) -> u64 {
        self.stage_us[stage as usize]
    }

    /// Sum of the six sequential stage spans. Parallel `eval_shard[i]`
    /// samples are excluded — they overlap the `eval` span.
    pub fn stages_total_us(&self) -> u64 {
        self.stage_us.iter().sum()
    }

    /// The inline breakdown attached to a response body when the
    /// request set `"trace": true`.
    pub fn breakdown_json(&self) -> Json {
        let mut fields = vec![
            ("id", json::s(format!("{:016x}", self.id))),
            ("stages", stages_json(&self.stage_us)),
        ];
        if self.n_shards > 0 {
            fields.push((
                "shard_us",
                Json::Arr(
                    self.shard_us[..self.n_shards]
                        .iter()
                        .map(|&u| json::num(u as f64))
                        .collect(),
                ),
            ));
        }
        json::obj(fields)
    }

    /// Publish the finished trace into the ring; returns the end-to-end
    /// total in microseconds measured from the trace clock's `t0`.
    /// Atomics only — no allocation.
    pub fn commit(&self, status: u16) -> u64 {
        let total_us = self.t0.elapsed().as_micros() as u64;
        let n = HEAD.fetch_add(1, Ordering::Relaxed);
        let slot = &RING[(n % RING_LEN as u64) as usize];
        slot.seq.fetch_add(1, Ordering::AcqRel); // odd: write in progress
        slot.id.store(self.id, Ordering::Relaxed);
        slot.status.store(status as u64, Ordering::Relaxed);
        slot.total_us.store(total_us, Ordering::Relaxed);
        for (dst, &src) in slot.stage_us.iter().zip(&self.stage_us) {
            dst.store(src, Ordering::Relaxed);
        }
        slot.n_shards.store(self.n_shards as u64, Ordering::Relaxed);
        for (dst, &src) in slot.shard_us.iter().zip(&self.shard_us) {
            dst.store(src, Ordering::Relaxed);
        }
        slot.seq.fetch_add(1, Ordering::Release); // even: published
        total_us
    }
}

fn stages_json(stage_us: &[u64; N_STAGES]) -> Json {
    json::obj(
        STAGE_NAMES
            .iter()
            .zip(stage_us)
            .map(|(&name, &us)| (name, json::num(us as f64)))
            .collect(),
    )
}

// ------------------------------------------------------ eval deadline

thread_local! {
    /// The deadline of the request currently evaluating on this thread.
    /// The router publishes it just before calling into a classifier
    /// (deadlines cannot ride the object-safe `Classifier` trait), and
    /// backends read it once at batch entry — the `Instant` is `Copy`,
    /// so shard closures capture it by value onto pool worker threads.
    static EVAL_DEADLINE: std::cell::Cell<Option<Instant>> = const { std::cell::Cell::new(None) };
}

/// Publish (or clear, with `None`) the calling thread's eval deadline.
/// Allocation-free. Callers must clear after the classifier returns so
/// the next request on this thread starts clean.
pub fn set_eval_deadline(deadline: Option<Instant>) {
    EVAL_DEADLINE.with(|d| d.set(deadline));
}

/// The eval deadline published on this thread, if any.
pub fn eval_deadline() -> Option<Instant> {
    EVAL_DEADLINE.with(|d| d.get())
}

// ---------------------------------------------------------------- ring

const RING_LEN: usize = 256;

struct Slot {
    /// Seqlock parity: even = published, odd = write in progress.
    seq: AtomicU64,
    id: AtomicU64,
    status: AtomicU64,
    total_us: AtomicU64,
    n_shards: AtomicU64,
    stage_us: [AtomicU64; N_STAGES],
    shard_us: [AtomicU64; MAX_TRACE_SHARDS],
}

impl Slot {
    const fn zero() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            id: AtomicU64::new(0),
            status: AtomicU64::new(0),
            total_us: AtomicU64::new(0),
            n_shards: AtomicU64::new(0),
            stage_us: [const { AtomicU64::new(0) }; N_STAGES],
            shard_us: [const { AtomicU64::new(0) }; MAX_TRACE_SHARDS],
        }
    }
}

static RING: [Slot; RING_LEN] = [const { Slot::zero() }; RING_LEN];
static HEAD: AtomicU64 = AtomicU64::new(0);

/// The last `n` committed traces, newest first, as a JSON array.
/// Lock-free and best-effort: a slot overwritten mid-read is skipped
/// rather than returned torn.
pub fn recent(n: usize) -> Json {
    let head = HEAD.load(Ordering::Acquire);
    let available = head.min(RING_LEN as u64);
    let want = n.min(available as usize);
    let mut out = Vec::with_capacity(want);
    let mut back = 0u64;
    while out.len() < want && back < available {
        let idx = ((head - 1 - back) % RING_LEN as u64) as usize;
        back += 1;
        let slot = &RING[idx];
        let seq0 = slot.seq.load(Ordering::Acquire);
        if seq0 == 0 || seq0 % 2 == 1 {
            continue; // never written, or a write is in flight
        }
        let id = slot.id.load(Ordering::Relaxed);
        let status = slot.status.load(Ordering::Relaxed);
        let total_us = slot.total_us.load(Ordering::Relaxed);
        let mut stage_us = [0u64; N_STAGES];
        for (dst, src) in stage_us.iter_mut().zip(&slot.stage_us) {
            *dst = src.load(Ordering::Relaxed);
        }
        let n_shards = (slot.n_shards.load(Ordering::Relaxed) as usize).min(MAX_TRACE_SHARDS);
        let mut shard_us = [0u64; MAX_TRACE_SHARDS];
        for (dst, src) in shard_us.iter_mut().zip(&slot.shard_us) {
            *dst = src.load(Ordering::Relaxed);
        }
        if slot.seq.load(Ordering::Acquire) != seq0 {
            continue; // overwritten while reading
        }
        let mut fields = vec![
            ("id", json::s(format!("{id:016x}"))),
            ("status", json::num(status as f64)),
            ("total_us", json::num(total_us as f64)),
            ("stages", stages_json(&stage_us)),
        ];
        if n_shards > 0 {
            fields.push((
                "shard_us",
                Json::Arr(
                    shard_us[..n_shards]
                        .iter()
                        .map(|&u| json::num(u as f64))
                        .collect(),
                ),
            ));
        }
        out.push(json::obj(fields));
    }
    Json::Arr(out)
}

// ----------------------------------------------------------------- ids

static ID_COUNTER: AtomicU64 = AtomicU64::new(0);
static ID_SEED: OnceLock<u64> = OnceLock::new();

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// A fresh process-unique nonzero trace id.
pub fn next_id() -> u64 {
    let seed = *ID_SEED.get_or_init(|| {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        splitmix64(t ^ ((std::process::id() as u64) << 32))
    });
    let id = splitmix64(seed.wrapping_add(ID_COUNTER.fetch_add(1, Ordering::Relaxed)));
    if id == 0 {
        1
    } else {
        id
    }
}

/// Derive the trace id from a client-supplied `X-Request-Id`: short hex
/// ids parse verbatim so client and server agree on the number,
/// anything else hashes (FNV-1a 64). Always nonzero.
pub fn id_from_header(s: &str) -> u64 {
    let t = s.trim();
    if !t.is_empty() && t.len() <= 16 && t.bytes().all(|b| b.is_ascii_hexdigit()) {
        if let Ok(v) = u64::from_str_radix(t, 16) {
            if v != 0 {
                return v;
            }
        }
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1_0000_0001_b3);
    }
    if h == 0 {
        1
    } else {
        h
    }
}

// -------------------------------------------------- per-shard timing

struct ShardStat {
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl ShardStat {
    const fn zero() -> ShardStat {
        ShardStat {
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

static SHARD_STATS: [ShardStat; MAX_SHARD_STATS] = [const { ShardStat::zero() }; MAX_SHARD_STATS];
static LAST_RUN_US: [AtomicU64; MAX_TRACE_SHARDS] = [const { AtomicU64::new(0) }; MAX_TRACE_SHARDS];
static LAST_RUN_N: AtomicU64 = AtomicU64::new(0);

/// Record one shard's evaluation time (called by the pool on every
/// sharded batch). Atomics only — safe on the hot eval path.
pub fn record_shard(shard: usize, us: u64) {
    if shard < MAX_TRACE_SHARDS {
        LAST_RUN_US[shard].store(us, Ordering::Relaxed);
    }
    if shard >= MAX_SHARD_STATS {
        return;
    }
    let s = &SHARD_STATS[shard];
    s.count.fetch_add(1, Ordering::Relaxed);
    s.sum_us.fetch_add(us, Ordering::Relaxed);
    s.max_us.fetch_max(us, Ordering::Relaxed);
}

/// Note that a sharded run with `n` shards began (sizes the last-run
/// sample returned by [`sample_last_run`]).
pub fn note_shard_run(n: usize) {
    LAST_RUN_N.store(n.min(MAX_TRACE_SHARDS) as u64, Ordering::Relaxed);
}

/// Copy the most recent sharded run's per-shard timings into `out`,
/// returning the shard count. Best effort under concurrency: samples
/// from overlapping runs may interleave (diagnostic data, not metrics).
pub fn sample_last_run(out: &mut [u64; MAX_TRACE_SHARDS]) -> usize {
    let n = (LAST_RUN_N.load(Ordering::Relaxed) as usize).min(MAX_TRACE_SHARDS);
    for (dst, src) in out.iter_mut().zip(&LAST_RUN_US).take(n) {
        *dst = src.load(Ordering::Relaxed);
    }
    n
}

/// Aggregate timing snapshot for one shard index.
#[derive(Debug, Clone, Copy)]
pub struct ShardSnapshot {
    /// Shard index within the pool's contiguous split.
    pub shard: usize,
    /// Sharded batches this index has participated in.
    pub count: u64,
    /// Total microseconds spent evaluating on this shard.
    pub sum_us: u64,
    /// Slowest single evaluation on this shard.
    pub max_us: u64,
}

/// Snapshot of every shard index that has recorded at least one sample.
pub fn shard_stats() -> Vec<ShardSnapshot> {
    SHARD_STATS
        .iter()
        .enumerate()
        .map(|(shard, s)| ShardSnapshot {
            shard,
            count: s.count.load(Ordering::Relaxed),
            sum_us: s.sum_us.load(Ordering::Relaxed),
            max_us: s.max_us.load(Ordering::Relaxed),
        })
        .filter(|s| s.count > 0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_sum_never_exceeds_committed_total() {
        let mut t = ReqTrace::new(next_id());
        t.record(Stage::Parse);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.record(Stage::Eval);
        t.record(Stage::Serialize);
        let total = t.commit(200);
        assert!(t.stage_us(Stage::Eval) >= 1_000, "{t:?}");
        assert!(
            t.stages_total_us() <= total,
            "stages {} vs total {total}",
            t.stages_total_us()
        );
    }

    #[test]
    fn mark_skips_idle_gaps() {
        let mut t = ReqTrace::new(1);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.mark(); // the sleep above is keep-alive idle, not a stage
        t.record(Stage::Parse);
        assert!(t.stage_us(Stage::Parse) < 2_000, "{t:?}");
    }

    #[test]
    fn deadlines_ride_the_trace_and_expire() {
        let mut t = ReqTrace::new(1);
        assert_eq!(t.deadline(), None);
        assert!(!t.expired(), "no deadline never expires");
        t.set_deadline(Instant::now() + std::time::Duration::from_secs(3600));
        assert!(!t.expired());
        t.set_deadline(Instant::now() - std::time::Duration::from_millis(1));
        assert!(t.expired());
    }

    #[test]
    fn ring_returns_committed_traces_newest_first() {
        let ids = [next_id(), next_id(), next_id()];
        for (k, &id) in ids.iter().enumerate() {
            let mut t = ReqTrace::new(id);
            t.record(Stage::Parse);
            t.set_shards(&[5, 7]);
            t.commit(200 + k as u16);
        }
        let arr_json = recent(RING_LEN);
        let arr = arr_json.as_arr().unwrap();
        // other tests commit concurrently: find ours by id
        let pos = |id: u64| {
            arr.iter()
                .position(|t| t.get_str("id") == Some(format!("{id:016x}").as_str()))
        };
        let (p0, p1, p2) = (pos(ids[0]), pos(ids[1]), pos(ids[2]));
        assert!(p0.is_some() && p1.is_some() && p2.is_some(), "{arr_json:?}");
        assert!(p2 < p1 && p1 < p0, "newest first: {p0:?} {p1:?} {p2:?}");
        let t2 = &arr[p2.unwrap()];
        assert_eq!(t2.get_i64("status"), Some(202));
        assert!(t2.get_i64("total_us").is_some());
        assert!(t2.get("stages").unwrap().get_i64("parse").is_some());
        assert_eq!(t2.get("shard_us").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn recent_caps_at_request_and_ring_size() {
        let mut t = ReqTrace::new(42);
        t.commit(200);
        let two = recent(2);
        assert!(two.as_arr().unwrap().len() <= 2);
        assert!(recent(100_000).as_arr().unwrap().len() <= RING_LEN);
    }

    #[test]
    fn header_ids_parse_hex_or_hash_nonzero() {
        assert_eq!(id_from_header("00ab"), 0xab);
        assert_eq!(id_from_header("deadbeefdeadbeef"), 0xdead_beef_dead_beef);
        // too long for u64 hex -> hashed, stable, nonzero
        let h = id_from_header("3aa2f71e-90b2-4b6e-long-opaque-id");
        assert_ne!(h, 0);
        assert_eq!(h, id_from_header("3aa2f71e-90b2-4b6e-long-opaque-id"));
        assert_ne!(h, id_from_header("a different id"));
        assert_ne!(id_from_header(""), 0);
        assert_ne!(id_from_header("0"), 0, "zero id must be remapped");
    }

    #[test]
    fn generated_ids_are_unique_and_nonzero() {
        let a = next_id();
        let b = next_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn shard_table_accumulates_and_samples() {
        record_shard(3, 120);
        record_shard(3, 80);
        note_shard_run(4);
        let stats = shard_stats();
        let s3 = stats.iter().find(|s| s.shard == 3).unwrap();
        assert!(s3.count >= 2);
        assert!(s3.sum_us >= 200);
        assert!(s3.max_us >= 120);
        let mut sample = [0u64; MAX_TRACE_SHARDS];
        let n = sample_last_run(&mut sample);
        assert!(n <= MAX_TRACE_SHARDS);
        // concurrent pool tests may shrink the last-run size; only when
        // our note survived can shard 3's sample be asserted
        if n > 3 {
            assert!(sample[3] > 0, "shard 3 recorded just above");
        }
    }

    #[test]
    fn set_shards_truncates_to_capacity() {
        let mut t = ReqTrace::new(1);
        t.set_shards(&[1u64; 40]);
        let b = t.breakdown_json();
        assert_eq!(
            b.get("shard_us").unwrap().as_arr().unwrap().len(),
            MAX_TRACE_SHARDS
        );
        assert_eq!(b.get_str("id"), Some("0000000000000001"));
    }
}
