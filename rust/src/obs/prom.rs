//! Prometheus text-format (version 0.0.4) rendering.
//!
//! A small, serve-agnostic text builder: callers stream `# HELP`/
//! `# TYPE` headers and samples through a [`PromWriter`] and take the
//! finished body. The one domain-aware piece is
//! [`PromWriter::log2_histogram`], which renders the crate's log₂
//! microsecond buckets (`bucket i` holds values in `[2^i, 2^(i+1)-1]`)
//! as proper cumulative `le` buckets: the upper bound of bucket `i` is
//! `2^(i+1)-1`, the final (overflow) bucket folds into `+Inf`, and
//! `_sum`/`_count` ride along, so `histogram_quantile()` works
//! server-side exactly as the JSON quantiles do in-process.

/// Incremental Prometheus text-format builder.
#[derive(Debug)]
pub struct PromWriter {
    out: String,
}

impl Default for PromWriter {
    fn default() -> Self {
        PromWriter::new()
    }
}

impl PromWriter {
    /// An empty exposition body.
    pub fn new() -> PromWriter {
        PromWriter {
            out: String::with_capacity(4096),
        }
    }

    /// Emit the `# HELP` / `# TYPE` header for a metric family. Call
    /// once per family, before its samples.
    pub fn header(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(help);
        self.out.push('\n');
        self.out.push_str("# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// Emit one sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                escape_label_into(&mut self.out, v);
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// Header + single unlabelled sample for a counter.
    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.header(name, "counter", help);
        self.sample(name, &[], value as f64);
    }

    /// Header + single unlabelled sample for a gauge.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, "gauge", help);
        self.sample(name, &[], value);
    }

    /// Render one log₂-bucketed histogram series (`buckets[i]` counts
    /// values in `[2^i, 2^(i+1)-1]`; the last bucket is the overflow
    /// tail) as cumulative `_bucket{le=…}` samples plus `_sum`/`_count`.
    /// The `# TYPE … histogram` header is the caller's (one per family,
    /// shared across label sets).
    pub fn log2_histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        buckets: &[u64],
        count: u64,
        sum: u64,
    ) {
        let bucket_name = format!("{name}_bucket");
        let n_finite = buckets.len().saturating_sub(1).min(63);
        let les: Vec<String> = (0..n_finite)
            .map(|i| format!("{}", (1u64 << (i + 1)) - 1))
            .collect();
        let mut cumulative = 0u64;
        for (i, &b) in buckets.iter().take(n_finite).enumerate() {
            cumulative += b;
            let mut with_le = labels.to_vec();
            with_le.push(("le", les[i].as_str()));
            self.sample(&bucket_name, &with_le, cumulative as f64);
        }
        let mut with_le = labels.to_vec();
        with_le.push(("le", "+Inf"));
        self.sample(&bucket_name, &with_le, count as f64);
        self.sample(&format!("{name}_sum"), labels, sum as f64);
        self.sample(&format!("{name}_count"), labels, count as f64);
    }

    /// The finished exposition body.
    pub fn finish(self) -> String {
        self.out
    }
}

/// Integer-exact rendering for whole values, shortest float otherwise.
fn fmt_value(v: f64) -> String {
    if v.is_finite() && v == v.trunc() && v.abs() < 9_007_199_254_740_992.0 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_exact_lines() {
        let mut w = PromWriter::new();
        w.counter("forest_requests_total", "requests served", 42);
        w.gauge("forest_uptime_seconds", "uptime", 1.5);
        let body = w.finish();
        assert!(body.contains("# HELP forest_requests_total requests served\n"));
        assert!(body.contains("# TYPE forest_requests_total counter\n"));
        assert!(body.contains("\nforest_requests_total 42\n"));
        assert!(body.contains("# TYPE forest_uptime_seconds gauge\n"));
        assert!(body.contains("forest_uptime_seconds 1.5\n"));
    }

    #[test]
    fn labels_render_and_escape() {
        let mut w = PromWriter::new();
        w.sample(
            "m",
            &[("backend", "dd"), ("weird", "a\"b\\c\nd")],
            3.0,
        );
        assert_eq!(
            w.finish(),
            "m{backend=\"dd\",weird=\"a\\\"b\\\\c\\nd\"} 3\n"
        );
    }

    #[test]
    fn log2_histogram_is_cumulative_with_power_of_two_bounds() {
        // 4 finite buckets + overflow tail: [1,2), [2,4), [4,8), [8,16), [16,inf)
        let buckets = [3u64, 1, 0, 2, 5];
        let count = 11u64;
        let mut w = PromWriter::new();
        w.header("lat_us", "histogram", "latency");
        w.log2_histogram("lat_us", &[], &buckets, count, 999);
        let body = w.finish();
        assert!(body.contains("lat_us_bucket{le=\"1\"} 3\n"));
        assert!(body.contains("lat_us_bucket{le=\"3\"} 4\n"));
        assert!(body.contains("lat_us_bucket{le=\"7\"} 4\n"));
        assert!(body.contains("lat_us_bucket{le=\"15\"} 6\n"));
        assert!(body.contains("lat_us_bucket{le=\"+Inf\"} 11\n"));
        assert!(body.contains("lat_us_sum 999\n"));
        assert!(body.contains("lat_us_count 11\n"));
        // +Inf (count) dominates every finite bucket: monotone
        let finite_max = 6.0;
        assert!(count as f64 >= finite_max);
    }

    #[test]
    fn labelled_histogram_keeps_base_labels_on_every_sample() {
        let mut w = PromWriter::new();
        w.log2_histogram("b_us", &[("backend", "frozen")], &[1, 1], 2, 3);
        let body = w.finish();
        assert!(body.contains("b_us_bucket{backend=\"frozen\",le=\"1\"} 1\n"));
        assert!(body.contains("b_us_bucket{backend=\"frozen\",le=\"+Inf\"} 2\n"));
        assert!(body.contains("b_us_sum{backend=\"frozen\"} 3\n"));
        assert!(body.contains("b_us_count{backend=\"frozen\"} 2\n"));
    }

    #[test]
    fn value_formatting_prefers_integers() {
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(42.0), "42");
        assert_eq!(fmt_value(0.25), "0.25");
    }
}
