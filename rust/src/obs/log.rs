//! Leveled structured logger — text or JSON lines on stderr.
//!
//! Std-only stand-in for the `log`/`env_logger` pairing (crates.io is
//! unreachable in the build environment). One process-global level gate
//! and format switch, initialised by [`init`] from `serve --log-level` /
//! `--log-json`; the `FOREST_ADD_LOG` environment variable overrides the
//! configured level when set to a valid name, `RUST_LOG`-style. Records
//! carry elapsed-time stamps and the emitting module path; JSON mode
//! emits one object per line so fleet log shippers ingest without a
//! parser. The `log_*!` macros (exported at the crate root, expanding
//! through the [`crate::util::logging`] shim) are the intended call
//! sites.

use crate::error::{Error, Result};
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    /// Parse a level name as used by `--log-level` and `FOREST_ADD_LOG`.
    pub fn parse(s: &str) -> Result<Level> {
        match s {
            "error" => Ok(Level::Error),
            "warn" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            "trace" => Ok(Level::Trace),
            _ => Err(Error::invalid(format!(
                "unknown log level {s:?} (expected error|warn|info|debug|trace)"
            ))),
        }
    }

    /// The lowercase level name (`"info"`, …).
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }

    fn from_env() -> Option<Level> {
        std::env::var("FOREST_ADD_LOG")
            .ok()
            .and_then(|s| Level::parse(&s).ok())
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static JSON_LINES: AtomicBool = AtomicBool::new(false);
static START: OnceLock<Instant> = OnceLock::new();

/// Install the configured level and output format (`serve` startup).
/// The `FOREST_ADD_LOG` environment override wins over `level` when set
/// to a valid name.
pub fn init(level: Level, json: bool) {
    set_max_level(Level::from_env().unwrap_or(level));
    JSON_LINES.store(json, Ordering::Relaxed);
}

/// Current max level, lazily initialised from the environment.
pub fn max_level() -> Level {
    let raw = MAX_LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = Level::from_env().unwrap_or(Level::Info);
        MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
        lvl
    } else {
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// Override the level programmatically (tests, `--quiet`).
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True when `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

/// Render one text record (pure, so the format is unit-testable).
fn render_text(t_s: f64, level: Level, target: &str, msg: &str) -> String {
    format!("[{:>8.3}s {} {}] {}", t_s, level.tag(), target, msg)
}

/// Render one JSON-lines record (pure; the escaping is the unit under
/// test).
fn render_json(t_s: f64, level: Level, target: &str, msg: &str) -> String {
    let mut out = String::with_capacity(msg.len() + target.len() + 48);
    out.push_str(&format!("{{\"t_s\":{t_s:.3},\"level\":\""));
    out.push_str(level.name());
    out.push_str("\",\"target\":\"");
    escape_json_into(&mut out, target);
    out.push_str("\",\"msg\":\"");
    escape_json_into(&mut out, msg);
    out.push_str("\"}");
    out
}

fn escape_json_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// Emit a record (used via the `log_*!` macros).
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t_s = start.elapsed().as_secs_f64();
    let line = if JSON_LINES.load(Ordering::Relaxed) {
        render_json(t_s, level, target, &msg.to_string())
    } else {
        render_text(t_s, level, target, &msg.to_string())
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn parse_accepts_every_name_and_rejects_junk() {
        for (name, want) in [
            ("error", Level::Error),
            ("warn", Level::Warn),
            ("info", Level::Info),
            ("debug", Level::Debug),
            ("trace", Level::Trace),
        ] {
            assert_eq!(Level::parse(name).unwrap(), want);
            assert_eq!(want.name(), name);
        }
        assert!(Level::parse("verbose").is_err());
        assert!(Level::parse("").is_err());
    }

    #[test]
    fn text_record_format_is_stable() {
        let line = render_text(1.5, Level::Warn, "forest_add::serve", "queue full");
        assert_eq!(line, "[   1.500s WARN  forest_add::serve] queue full");
    }

    #[test]
    fn json_record_escapes_and_parses() {
        let line = render_json(0.25, Level::Info, "a::b", "say \"hi\"\nback\\slash");
        let v = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(v.get_str("level"), Some("info"));
        assert_eq!(v.get_str("target"), Some("a::b"));
        assert_eq!(v.get_str("msg"), Some("say \"hi\"\nback\\slash"));
        assert!((v.get("t_s").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-9);
    }

    /// Global-state checks live in one test so they cannot race each
    /// other across the parallel test harness.
    #[test]
    fn global_level_gates_and_macros() {
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Error);
        crate::log_info!("hidden {}", 1);
        crate::log_error!("shown {}", 2);
        set_max_level(Level::Info);
        assert!(enabled(Level::Info));
    }
}
