//! Observability: structured logging, per-request tracing, and
//! Prometheus exposition for the serving stack.
//!
//! Three std-only pieces, threaded through both socket front-ends:
//!
//! - [`log`] — the leveled text/JSON-lines logger behind the crate's
//!   `log_*!` macros (`serve --log-level` / `--log-json`, with an
//!   `FOREST_ADD_LOG` environment override that always wins);
//! - [`trace`] — 64-bit request ids (accepted or generated as
//!   `X-Request-Id` and echoed on every response), monotonic per-stage
//!   spans recorded into a lock-free last-N ring (`GET /debug/trace?n=`,
//!   inline via the `"trace": true` request field), plus the global
//!   per-shard evaluation timing table fed by the worker pool;
//! - [`prom`] — Prometheus text-format rendering used by
//!   `GET /metrics?format=prometheus`.
//!
//! Layering: `obs` depends only on `util` and std; `net` may depend on
//! `obs`; `serve` depends on both. Everything on the request hot path
//! (stage recording, ring commits, shard timing) is fixed-size atomics
//! and arrays — zero allocations, enforced by the counting-allocator
//! test alongside the frozen sweep guarantees.

pub mod log;
pub mod prom;
pub mod trace;
