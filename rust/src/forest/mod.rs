//! Random Forests: bagging ensemble of CART trees with majority vote.
//!
//! The baseline classifier of the paper (§2): every tree is trained on a
//! bootstrap sample with random feature subspaces, and classification
//! evaluates **all** trees — cost linear in the forest size, which is
//! exactly what the ADD aggregation removes.

use crate::batch::RowMatrix;
use crate::classifier::{BackendKind, Classifier, ClassifierInfo, CostModel};
use crate::data::{Dataset, Schema};
use crate::error::{Error, Result};
use crate::runtime::pool;
use crate::tree::{DecisionTree, TreeLearner, TreeParams};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Minimum batch size before forest evaluation is sharded across the
/// worker pool (each row already costs a full walk of every tree, so the
/// crossover is far lower than the frozen sweep's).
const PAR_MIN_ROWS: usize = 64;

/// Minimum rows per parallel shard.
const PAR_ROWS_PER_SHARD: usize = 32;

/// A trained Random Forest.
#[derive(Debug, Clone)]
pub struct RandomForest {
    /// Member trees (evaluated independently; majority vote aggregates).
    pub trees: Vec<DecisionTree>,
    /// Schema the forest was trained on (feature names for predicate
    /// rendering, class labels for output).
    pub schema: Schema,
}

/// Builder-style trainer for [`RandomForest`].
#[derive(Debug, Clone)]
pub struct ForestLearner {
    n_trees: usize,
    params: TreeParams,
    bootstrap: bool,
    seed: u64,
}

impl Default for ForestLearner {
    fn default() -> Self {
        ForestLearner {
            n_trees: 100,
            params: TreeParams::default(),
            bootstrap: true,
            seed: 0,
        }
    }
}

impl ForestLearner {
    /// Set the number of trees.
    pub fn trees(mut self, n: usize) -> Self {
        self.n_trees = n;
        self
    }

    /// Set the RNG seed (forests are fully reproducible per seed).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the per-tree maximum depth (`0` = unlimited).
    pub fn max_depth(mut self, d: usize) -> Self {
        self.params.max_depth = d;
        self
    }

    /// Set the minimum rows per leaf.
    pub fn min_samples_leaf(mut self, n: usize) -> Self {
        self.params.min_samples_leaf = n.max(1);
        self
    }

    /// Set candidate features per node (`0` = `⌈√F⌉`).
    pub fn k_features(mut self, k: usize) -> Self {
        self.params.k_features = k;
        self
    }

    /// Enable/disable bootstrap sampling (disabled = every tree sees all rows,
    /// randomness only from the feature subspace).
    pub fn bootstrap(mut self, on: bool) -> Self {
        self.bootstrap = on;
        self
    }

    /// Train on a dataset.
    pub fn fit(&self, data: &Dataset) -> RandomForest {
        assert!(data.n_rows() > 0, "cannot train on an empty dataset");
        let root = Rng::new(self.seed);
        let trees = (0..self.n_trees)
            .map(|t| {
                // Every tree gets an independent stream -> identical forests
                // regardless of evaluation order.
                let mut rng = root.fork(t as u64);
                let rows: Vec<usize> = if self.bootstrap {
                    rng.bootstrap(data.n_rows())
                } else {
                    (0..data.n_rows()).collect()
                };
                TreeLearner::new(data, self.params.clone(), rng).fit(&rows)
            })
            .collect();
        RandomForest {
            trees,
            schema: data.schema.clone(),
        }
    }
}

impl RandomForest {
    /// Number of trees.
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Number of classes.
    pub fn n_classes(&self) -> usize {
        self.schema.n_classes()
    }

    /// Total node count over all trees — the paper's Fig. 7/Table 2 "size"
    /// for the Random Forest structure.
    pub fn n_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.n_nodes()).sum()
    }

    /// Per-class vote counts for one row.
    pub fn votes(&self, x: &[f32]) -> Vec<u32> {
        let mut v = vec![0u32; self.n_classes()];
        for tree in &self.trees {
            v[tree.predict(x) as usize] += 1;
        }
        v
    }

    /// Majority-vote prediction (ties toward the lowest class index,
    /// matching the ADD majority abstraction and the L1 kernel's argmax).
    pub fn predict(&self, x: &[f32]) -> u32 {
        let v = self.votes(x);
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i as u32)
            .unwrap_or(0)
    }

    /// Batch prediction over a flat row matrix, sharded across the
    /// evaluation worker pool when the batch is large enough to amortise
    /// the fan-out. Shards are contiguous row ranges writing disjoint
    /// output slices, so the result is bit-identical to looping
    /// [`predict`](Self::predict) regardless of thread count.
    pub fn predict_batch(&self, rows: RowMatrix<'_>) -> Vec<u32> {
        let mut out = vec![0u32; rows.n_rows()];
        let sharded = rows.n_rows() >= PAR_MIN_ROWS
            && pool::run_sharded(rows, &mut out, PAR_ROWS_PER_SHARD, |shard, out_chunk| {
                for (slot, row) in out_chunk.iter_mut().zip(shard.iter()) {
                    *slot = self.predict(row);
                }
            });
        if !sharded {
            for (slot, row) in out.iter_mut().zip(rows.iter()) {
                *slot = self.predict(row);
            }
        }
        out
    }

    /// Prediction with the paper's §6 step count: internal nodes visited in
    /// every tree, plus `n` additional reads for the majority vote.
    pub fn predict_with_steps(&self, x: &[f32]) -> (u32, usize) {
        let mut votes = vec![0u32; self.n_classes()];
        let mut steps = 0usize;
        for tree in &self.trees {
            let (c, s) = tree.walk(x);
            votes[c as usize] += 1;
            steps += s;
        }
        steps += self.trees.len(); // one read per tree result (§6)
        let pred = votes
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i as u32)
            .unwrap_or(0);
        (pred, steps)
    }

    /// Mean step count over a dataset (the paper's reported measure).
    /// Delegates to [`crate::classifier::mean_steps`] — the single
    /// implementation of the §6 accounting.
    pub fn mean_steps(&self, data: &Dataset) -> f64 {
        crate::classifier::mean_steps(self, data)
            .expect("forest evaluation is infallible")
            .expect("forest steps are always meterable")
    }

    /// Classification accuracy on a dataset.
    pub fn accuracy(&self, data: &Dataset) -> f64 {
        crate::classifier::accuracy(self, data).expect("forest evaluation is infallible")
    }

    /// Prefix sub-forest (first `n` trees) — used for the Fig. 6/7 sweeps so
    /// the size-`k` forest is always a prefix of the size-`k+1` forest,
    /// matching the paper's incremental-aggregation setting.
    pub fn prefix(&self, n: usize) -> RandomForest {
        RandomForest {
            trees: self.trees[..n.min(self.trees.len())].to_vec(),
            schema: self.schema.clone(),
        }
    }

    /// JSON encoding (model persistence for the CLI train/compile workflow).
    /// Regression forests additionally carry the per-class value table as
    /// a `"values"` field; classification encodings are unchanged from
    /// earlier releases, so old model files round-trip byte-for-byte.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            (
                "classes",
                Json::Arr(self.schema.classes.iter().map(|c| json::s(c.clone())).collect()),
            ),
            (
                "features",
                Json::Arr(
                    self.schema
                        .features
                        .iter()
                        .map(|f| {
                            let kind = match &f.kind {
                                crate::data::FeatureKind::Numeric => json::s("numeric"),
                                crate::data::FeatureKind::Categorical { values } => Json::Arr(
                                    values.iter().map(|v| json::s(v.clone())).collect(),
                                ),
                            };
                            json::obj(vec![("name", json::s(f.name.clone())), ("kind", kind)])
                        })
                        .collect(),
                ),
            ),
            (
                "trees",
                Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()),
            ),
        ];
        if let Some(values) = self.schema.values() {
            fields.push((
                "values",
                Json::Arr(values.iter().map(|&v| Json::Num(v as f64)).collect()),
            ));
        }
        json::obj(fields)
    }

    /// JSON decoding.
    pub fn from_json(v: &Json) -> Result<RandomForest> {
        let classes: Vec<String> = v
            .get("classes")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::parse("forest: missing classes"))?
            .iter()
            .map(|c| c.as_str().map(String::from))
            .collect::<Option<_>>()
            .ok_or_else(|| Error::parse("forest: bad class label"))?;
        let features = v
            .get("features")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::parse("forest: missing features"))?
            .iter()
            .map(|f| {
                let name = f
                    .get_str("name")
                    .ok_or_else(|| Error::parse("feature: missing name"))?
                    .to_string();
                let kind = match f.get("kind") {
                    Some(Json::Str(s)) if s == "numeric" => crate::data::FeatureKind::Numeric,
                    Some(Json::Arr(vals)) => crate::data::FeatureKind::Categorical {
                        values: vals
                            .iter()
                            .map(|v| v.as_str().map(String::from))
                            .collect::<Option<_>>()
                            .ok_or_else(|| Error::parse("feature: bad categorical value"))?,
                    },
                    _ => return Err(Error::parse("feature: bad kind")),
                };
                Ok(crate::data::Feature { name, kind })
            })
            .collect::<Result<Vec<_>>>()?;
        let trees = v
            .get("trees")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::parse("forest: missing trees"))?
            .iter()
            .map(DecisionTree::from_json)
            .collect::<Result<Vec<_>>>()?;
        // Optional regression value table ("values" absent = classification,
        // which keeps pre-existing model files parsing identically).
        let task = match v.get("values").and_then(Json::as_arr) {
            Some(vals) => crate::data::Task::Regression {
                values: vals
                    .iter()
                    .map(|v| v.as_f64().map(|f| f as f32))
                    .collect::<Option<_>>()
                    .ok_or_else(|| Error::parse("forest: bad regression value"))?,
            },
            None => crate::data::Task::Classification,
        };
        let schema = Schema {
            features,
            classes,
            task,
        };
        schema.validate_task().map_err(|e| {
            Error::parse(format!("forest: invalid regression value table: {e}"))
        })?;
        for t in &trees {
            if t.n_features != schema.n_features() || t.n_classes != schema.n_classes() {
                return Err(Error::SchemaMismatch(
                    "tree dimensions do not match forest schema".into(),
                ));
            }
        }
        Ok(RandomForest { trees, schema })
    }

    /// Save to a JSON file.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Load from a JSON file.
    pub fn load(path: &str) -> Result<RandomForest> {
        let text = std::fs::read_to_string(path)?;
        RandomForest::from_json(&Json::parse(&text)?)
    }
}

/// The baseline backend: every classification walks all `n` trees and
/// pays `n` extra reads for the majority vote (§6).
impl Classifier for RandomForest {
    fn info(&self) -> ClassifierInfo {
        ClassifierInfo {
            backend: BackendKind::Forest,
            label: format!("Random Forest ({} trees)", self.n_trees()),
            n_features: self.schema.n_features(),
            n_classes: self.n_classes(),
            size_nodes: self.n_nodes(),
            cost: CostModel {
                max_steps: Some(
                    self.trees.iter().map(DecisionTree::depth).sum::<usize>() + self.n_trees(),
                ),
                aggregation_reads: self.n_trees(),
                preferred_batch: 1,
            },
        }
    }

    fn classify_with_steps(&self, x: &[f32]) -> Result<(u32, Option<usize>)> {
        let (class, steps) = self.predict_with_steps(x);
        Ok((class, Some(steps)))
    }

    fn classify_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        Ok(self.predict_batch(rows))
    }

    fn votes(&self, x: &[f32]) -> Result<Vec<u32>> {
        Ok(RandomForest::votes(self, x))
    }

    fn task_values(&self) -> Option<Vec<f32>> {
        self.schema.values().map(<[f32]>::to_vec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{datasets, split};

    #[test]
    fn forest_beats_chance_and_single_tree_on_holdout() {
        let ds = datasets::iris();
        let (train, test) = split::train_test_split(&ds, 0.3, 11).unwrap();
        let forest = ForestLearner::default().trees(60).seed(4).fit(&train);
        let acc = forest.accuracy(&test);
        assert!(acc > 0.85, "holdout accuracy {acc}");
    }

    #[test]
    fn reproducible_per_seed() {
        let ds = datasets::lenses();
        let a = ForestLearner::default().trees(20).seed(9).fit(&ds);
        let b = ForestLearner::default().trees(20).seed(9).fit(&ds);
        for (ta, tb) in a.trees.iter().zip(&b.trees) {
            assert_eq!(ta, tb);
        }
        let c = ForestLearner::default().trees(20).seed(10).fit(&ds);
        assert!(a.trees.iter().zip(&c.trees).any(|(x, y)| x != y));
    }

    #[test]
    fn prefix_property_of_tree_streams() {
        // tree i of an n-tree forest == tree i of an m-tree forest (same seed)
        let ds = datasets::lenses();
        let small = ForestLearner::default().trees(5).seed(3).fit(&ds);
        let big = ForestLearner::default().trees(12).seed(3).fit(&ds);
        for i in 0..5 {
            assert_eq!(small.trees[i], big.trees[i], "tree {i}");
        }
        let pre = big.prefix(5);
        for i in 0..5 {
            assert_eq!(pre.trees[i], small.trees[i]);
        }
    }

    #[test]
    fn votes_sum_to_tree_count() {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(31).seed(0).fit(&ds);
        for i in [0, 75, 149] {
            let v = forest.votes(ds.row(i));
            assert_eq!(v.iter().sum::<u32>(), 31);
        }
    }

    #[test]
    fn steps_grow_linearly_with_forest_size() {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(64).seed(1).fit(&ds);
        let s16 = forest.prefix(16).mean_steps(&ds);
        let s64 = forest.mean_steps(&ds);
        let ratio = s64 / s16;
        assert!(
            (3.0..5.0).contains(&ratio),
            "expected ~4x step growth, got {ratio} ({s16} -> {s64})"
        );
    }

    #[test]
    fn steps_include_majority_reads() {
        // A forest of single-leaf trees walks 0 internal nodes but still pays
        // n reads for the majority vote (§6 metric definition).
        let ds = datasets::iris();
        let rows: Vec<usize> = (0..50).collect(); // pure setosa
        let pure = ds.select(&rows);
        let forest = ForestLearner::default().trees(10).seed(0).fit(&pure);
        let (pred, steps) = forest.predict_with_steps(pure.row(0));
        assert_eq!(pred, 0);
        assert_eq!(steps, 10);
    }

    #[test]
    fn json_roundtrip() {
        let ds = datasets::lenses();
        let forest = ForestLearner::default().trees(7).seed(2).fit(&ds);
        let text = forest.to_json().to_string_pretty();
        let back = RandomForest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.n_trees(), 7);
        for i in 0..ds.n_rows() {
            assert_eq!(forest.predict(ds.row(i)), back.predict(ds.row(i)));
        }
        assert_eq!(forest.schema, back.schema);
    }

    #[test]
    fn classifier_trait_matches_inherent_predict() {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(9).seed(6).fit(&ds);
        let info = Classifier::info(&forest);
        assert_eq!(info.backend, BackendKind::Forest);
        assert_eq!(info.n_features, 4);
        assert_eq!(info.n_classes, 3);
        assert_eq!(info.size_nodes, forest.n_nodes());
        assert_eq!(info.cost.aggregation_reads, 9);
        for i in (0..ds.n_rows()).step_by(23) {
            let (c, steps) = forest.classify_with_steps(ds.row(i)).unwrap();
            let (want_c, want_s) = forest.predict_with_steps(ds.row(i));
            assert_eq!((c, steps), (want_c, Some(want_s)));
            assert!(steps.unwrap() <= info.cost.max_steps.unwrap());
        }
    }

    #[test]
    fn predict_batch_matches_per_row_predict_at_every_scale() {
        let ds = datasets::iris();
        let forest = ForestLearner::default().trees(15).seed(8).fit(&ds);
        // small batch: serial path
        let small = ds.matrix().slice(0, 10);
        let got = forest.predict_batch(small);
        for (i, row) in small.iter().enumerate() {
            assert_eq!(got[i], forest.predict(row), "row {i}");
        }
        // tiled batch past the parallel crossover: sharded path,
        // bit-identical to the per-row walks
        let tiled = crate::bench_support::tile_rows(&ds, 512, 11);
        let big = tiled.as_matrix();
        let got = forest.predict_batch(big);
        for (i, row) in big.iter().enumerate() {
            assert_eq!(got[i], forest.predict(row), "row {i}");
        }
        assert!(forest.predict_batch(crate::batch::RowMatrix::empty()).is_empty());
    }

    #[test]
    fn regression_schema_survives_json_roundtrip() {
        let spec = crate::data::synth::RegressionSpec {
            rows: 120,
            ..Default::default()
        };
        let ds = crate::data::synth::regression(&spec).unwrap();
        let forest = ForestLearner::default().trees(9).seed(3).fit(&ds);
        assert!(forest.schema.task.is_regression());
        let text = forest.to_json().to_string_pretty();
        let back = RandomForest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.schema, forest.schema);
        // classification encodings gain no new field
        let cls = ForestLearner::default().trees(3).seed(0).fit(&datasets::lenses());
        assert!(cls.to_json().get("values").is_none());
        // the trait surface delegates to the inherent vote counter
        let v = Classifier::votes(&forest, ds.row(0)).unwrap();
        assert_eq!(v, RandomForest::votes(&forest, ds.row(0)));
        assert_eq!(v.iter().sum::<u32>(), 9);
    }

    #[test]
    fn no_bootstrap_mode() {
        let ds = datasets::lenses();
        let forest = ForestLearner::default()
            .trees(5)
            .bootstrap(false)
            .k_features(4)
            .seed(0)
            .fit(&ds);
        // all-features + full data -> every tree is identical plain CART
        for t in &forest.trees[1..] {
            assert_eq!(*t, forest.trees[0]);
        }
        assert_eq!(forest.accuracy(&ds), 1.0);
    }
}
