//! Std-only async networking substrate for the serving front-end.
//!
//! No async runtime is available offline, so this module builds the
//! evented serving stack from first principles on `std::net` plus two
//! raw readiness syscalls:
//!
//! - [`poll`] — epoll (linux) / kqueue (macos) readiness wrapper, FFI in
//!   the style of [`crate::runtime::mmap`]: a tiny, level-triggered
//!   surface with a [`poll::supported`] capability probe;
//! - [`proto`] — transport-independent protocol layer: an incremental
//!   HTTP/1.1 request parser (keep-alive, pipelining) and the compact
//!   `application/octet-stream` row frame codec that deserialises
//!   batches straight into [`crate::batch::RowMatrixBuf`];
//! - [`conn`] — the nonblocking per-connection state machine (read →
//!   in-flight → write), shared buffer management and partial-write
//!   tracking;
//! - [`event_loop`] — the event loop + acceptor: one poller thread
//!   multiplexes every connection, parsed requests are dispatched to a
//!   worker pool through a bounded queue (admission control: a full
//!   queue is an immediate `429` + `Retry-After`, never unbounded
//!   queueing), responses travel back via a completion list and a
//!   self-pipe waker.
//!
//! The sync thread-per-connection server remains as the fallback where
//! no poller exists ([`poll::supported`] is `false`); both front-ends
//! share [`proto`], so they serve bit-identical responses.

pub mod conn;
pub mod poll;
pub mod proto;

#[cfg(any(target_os = "linux", all(target_os = "macos", target_pointer_width = "64")))]
#[path = "loop.rs"]
pub mod event_loop;

/// Observer of event-loop lifecycle: connection gauges and end-to-end
/// request latency. Implemented by
/// [`ServerMetrics`](crate::serve::metrics::ServerMetrics); the loop
/// only ever sees this trait, so the net layer stays independent of the
/// serving layer. All methods default to no-ops (tests can observe
/// selectively).
pub trait LoopObserver: Send + Sync {
    /// A connection was accepted.
    fn conn_opened(&self) {}
    /// A connection was closed (any cause: EOF, error, idle timeout).
    fn conn_closed(&self) {}
    /// One request was fully served (response flushed to the socket);
    /// `latency` spans parse-start → last byte written.
    fn request_served(&self, _latency: std::time::Duration) {}
    /// One request was shed with `429` by admission control.
    fn request_rejected(&self) {}
    /// One request was shed with `429` by the per-connection pipelining
    /// cap (the global dispatch queue was never consulted).
    fn request_rejected_conn(&self) {}
    /// A request entered the bounded dispatch queue.
    fn dispatch_enqueued(&self) {}
    /// A worker pulled a request off the dispatch queue.
    fn dispatch_dequeued(&self) {}
    /// `n` bytes were read from a client socket.
    fn bytes_read(&self, _n: u64) {}
    /// `n` bytes were written to a client socket.
    fn bytes_written(&self, _n: u64) {}
}

/// A no-op observer for tests and benches.
#[derive(Debug, Default)]
pub struct NullObserver;

impl LoopObserver for NullObserver {}
