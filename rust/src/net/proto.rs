//! Transport-independent protocol layer: incremental HTTP/1.1 request
//! parsing and the binary row frame codec.
//!
//! Both serving front-ends (the sync thread-per-connection loop and the
//! evented poller) feed raw socket bytes into [`RequestParser`] and
//! serialise [`Response`] values back — one parser, one serialiser,
//! bit-identical wire behaviour in both modes.
//!
//! ## The `application/octet-stream` row frame
//!
//! JSON cell parsing dominates request cost for large batches, so feature
//! rows can travel as a packed little-endian frame that deserialises
//! straight into a [`RowMatrixBuf`] without touching the JSON parser:
//!
//! | offset       | size              | content                          |
//! |--------------|-------------------|----------------------------------|
//! | 0            | 4                 | `n_rows` (u32, little-endian)    |
//! | 4            | 4                 | `n_features` (u32, little-endian)|
//! | 8            | `4·rows·features` | f32 cells, row-major, LE         |
//!
//! The frame must be exactly `8 + 4·n_rows·n_features` bytes; zero rows
//! or features, dimension overflow, and length mismatches are parse
//! errors (`400` over HTTP). NaN cells are accepted by policy — the
//! predicate evaluators define total behaviour for every f32 bit
//! pattern, so the wire layer does not second-guess them.

use crate::batch::{RowMatrix, RowMatrixBuf};
use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// Maximum accepted request head (request line + headers).
pub const MAX_HEAD: usize = 16 << 10;

/// Maximum accepted request body (1 MiB — batches of a few thousand rows).
pub const MAX_BODY: usize = 1 << 20;

/// Content type of the binary row frame.
pub const BINARY_ROWS: &str = "application/octet-stream";

/// Bytes of the row frame header (`u32 n_rows` + `u32 n_features`).
pub const ROW_FRAME_HEADER: usize = 8;

/// A fully parsed HTTP request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    /// Request method (`GET`, `POST`, …), uppercase as sent.
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw query string after `?` (empty when absent).
    pub query: String,
    /// Lowercased `Content-Type` with parameters stripped (empty when absent).
    pub content_type: String,
    /// Whether the connection survives this request (HTTP/1.1 default
    /// true unless `Connection: close`; HTTP/1.0 default false unless
    /// `Connection: keep-alive`).
    pub keep_alive: bool,
    /// Request body (exactly `Content-Length` bytes).
    pub body: Vec<u8>,
    /// Client-supplied `X-Request-Id` header, verbatim (None when
    /// absent — the server then mints one for the trace).
    pub request_id: Option<String>,
    /// Client-supplied `X-Deadline-Ms` header: how long the client is
    /// willing to wait, in milliseconds from admission. Admission caps
    /// it at `ServeConfig::reply_timeout_ms`; expired requests answer
    /// `504` without evaluating. Unparseable or zero values read as
    /// absent (the server deadline still applies).
    pub deadline_ms: Option<u64>,
}

impl Request {
    /// True when the body is a binary row frame.
    pub fn is_binary(&self) -> bool {
        self.content_type == BINARY_ROWS
    }

    /// Query parameter lookup (`?backend=dd&steps=true`). No percent
    /// decoding — the served parameter values (backend/model names,
    /// booleans) never need it.
    pub fn param(&self, key: &str) -> Option<&str> {
        self.query.split('&').find_map(|kv| {
            let (k, v) = kv.split_once('=').unwrap_or((kv, ""));
            (k == key).then_some(v)
        })
    }
}

/// Parsed head awaiting its body.
#[derive(Debug)]
struct Head {
    method: String,
    path: String,
    query: String,
    content_type: String,
    keep_alive: bool,
    content_length: usize,
    request_id: Option<String>,
    deadline_ms: Option<u64>,
    /// Bytes consumed by the head, including the `\r\n\r\n` terminator.
    head_len: usize,
}

/// Incremental HTTP/1.1 request parser: push raw socket bytes in, take
/// complete requests out. Bytes beyond one request stay buffered
/// (pipelining / keep-alive), so a single parser serves a connection's
/// whole lifetime.
#[derive(Debug, Default)]
pub struct RequestParser {
    buf: Vec<u8>,
    head: Option<Head>,
}

impl RequestParser {
    /// A fresh parser.
    pub fn new() -> RequestParser {
        RequestParser::default()
    }

    /// Buffer more bytes from the transport.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// True between requests (nothing buffered, no partial head) — the
    /// idle-timeout policy closes idle connections silently but answers
    /// a stalled mid-request connection with `408`.
    pub fn is_idle(&self) -> bool {
        self.buf.is_empty() && self.head.is_none()
    }

    /// Try to take the next complete request. `Ok(None)` means more
    /// bytes are needed; `Err` means the stream is malformed and the
    /// connection must close after an error response.
    pub fn try_next(&mut self) -> Result<Option<Request>> {
        if self.head.is_none() {
            let Some(head_end) = find_head_end(&self.buf) else {
                if self.buf.len() > MAX_HEAD {
                    return Err(Error::parse(format!(
                        "request head exceeds {MAX_HEAD} bytes"
                    )));
                }
                return Ok(None);
            };
            self.head = Some(parse_head(&self.buf[..head_end], head_end + 4)?);
        }
        let total = {
            let head = self.head.as_ref().expect("head parsed above");
            head.head_len + head.content_length
        };
        if self.buf.len() < total {
            return Ok(None);
        }
        let head = self.head.take().expect("head parsed above");
        let body = self.buf[head.head_len..total].to_vec();
        self.buf.drain(..total);
        Ok(Some(Request {
            method: head.method,
            path: head.path,
            query: head.query,
            content_type: head.content_type,
            keep_alive: head.keep_alive,
            body,
            request_id: head.request_id,
            deadline_ms: head.deadline_ms,
        }))
    }
}

/// Position of the head terminator (`\r\n\r\n`), if buffered.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn parse_head(head: &[u8], head_len: usize) -> Result<Head> {
    let text = std::str::from_utf8(head)
        .map_err(|_| Error::parse("request head is not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| Error::parse("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| Error::parse("request line missing path"))?;
    let version = parts
        .next()
        .ok_or_else(|| Error::parse("request line missing HTTP version"))?;
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => {
            return Err(Error::parse(format!(
                "unsupported HTTP version '{other}'"
            )))
        }
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let mut content_length = 0usize;
    let mut content_type = String::new();
    let mut connection = String::new();
    let mut request_id = None;
    let mut deadline_ms = None;
    for line in lines {
        let Some((k, v)) = line.split_once(':') else {
            continue;
        };
        let v = v.trim();
        if k.eq_ignore_ascii_case("content-length") {
            content_length = v
                .parse()
                .map_err(|_| Error::parse(format!("bad content-length '{v}'")))?;
        } else if k.eq_ignore_ascii_case("content-type") {
            // strip parameters (`; charset=...`) and normalise case
            content_type = v
                .split(';')
                .next()
                .unwrap_or("")
                .trim()
                .to_ascii_lowercase();
        } else if k.eq_ignore_ascii_case("connection") {
            connection = v.to_ascii_lowercase();
        } else if k.eq_ignore_ascii_case("x-request-id") && !v.is_empty() {
            request_id = Some(v.to_string());
        } else if k.eq_ignore_ascii_case("x-deadline-ms") {
            // lenient by design: a garbled client hint must not 400 a
            // request the server deadline would still bound
            deadline_ms = v.parse::<u64>().ok().filter(|&ms| ms > 0);
        }
    }
    if content_length > MAX_BODY {
        return Err(Error::parse(format!(
            "body too large ({content_length} bytes, limit {MAX_BODY})"
        )));
    }
    let keep_alive = match connection.as_str() {
        "close" => false,
        "keep-alive" => true,
        _ => http11,
    };
    Ok(Head {
        method,
        path,
        query,
        content_type,
        keep_alive,
        content_length,
        request_id,
        deadline_ms,
        head_len,
    })
}

/// Decode a binary row frame into an owned flat batch. See the module
/// docs for the byte layout; every malformation is an `Err`, never a
/// panic, and NaN cells pass through by policy.
pub fn decode_rows(body: &[u8]) -> Result<RowMatrixBuf> {
    if body.len() < ROW_FRAME_HEADER {
        return Err(Error::parse(format!(
            "row frame truncated: {} bytes, header alone is {ROW_FRAME_HEADER} (u32 n_rows, u32 n_features)",
            body.len()
        )));
    }
    let n_rows = u32::from_le_bytes(body[0..4].try_into().expect("4 bytes")) as usize;
    let n_features = u32::from_le_bytes(body[4..8].try_into().expect("4 bytes")) as usize;
    if n_rows == 0 {
        return Err(Error::parse("row frame declares 0 rows"));
    }
    if n_features == 0 {
        return Err(Error::parse("row frame declares 0 features"));
    }
    let cell_bytes = n_rows
        .checked_mul(n_features)
        .and_then(|c| c.checked_mul(4))
        .filter(|&c| c <= MAX_BODY)
        .ok_or_else(|| {
            Error::parse(format!(
                "row frame dimensions overflow: {n_rows} rows x {n_features} features"
            ))
        })?;
    if body.len() - ROW_FRAME_HEADER != cell_bytes {
        return Err(Error::parse(format!(
            "row frame length mismatch: {n_rows} rows x {n_features} features needs {} bytes, got {}",
            ROW_FRAME_HEADER + cell_bytes,
            body.len()
        )));
    }
    let mut buf = RowMatrixBuf::with_capacity(n_features, n_rows);
    for row in body[ROW_FRAME_HEADER..].chunks_exact(4 * n_features) {
        buf.push_row_le_bytes(row)?;
    }
    Ok(buf)
}

/// Encode a batch as a binary row frame (the client side of
/// [`decode_rows`]; used by the keep-alive client, the loadgen command
/// and tests).
pub fn encode_rows(m: RowMatrix<'_>) -> Result<Vec<u8>> {
    let n_rows = u32::try_from(m.n_rows())
        .map_err(|_| Error::invalid("row frame holds at most u32::MAX rows"))?;
    let n_features = u32::try_from(m.n_features())
        .map_err(|_| Error::invalid("row frame holds at most u32::MAX features"))?;
    let mut out = Vec::with_capacity(ROW_FRAME_HEADER + 4 * m.data().len());
    out.extend_from_slice(&n_rows.to_le_bytes());
    out.extend_from_slice(&n_features.to_le_bytes());
    for cell in m.data() {
        out.extend_from_slice(&cell.to_le_bytes());
    }
    Ok(out)
}

/// A response ready for serialisation. Always carries an explicit
/// `Content-Length`, so keep-alive framing is unambiguous.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// Response body bytes.
    pub body: Vec<u8>,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// `Retry-After` header in seconds (the `429` backpressure contract).
    pub retry_after_s: Option<u32>,
    /// `X-Request-Id` header value: the client's id echoed verbatim, or
    /// the server-minted trace id. Lives in the head only — bodies stay
    /// bit-identical across front-ends and request ids.
    pub request_id: Option<String>,
    /// `X-Served-By` header value: set when a circuit breaker rerouted
    /// the request to a fallback backend, naming the backend that
    /// actually evaluated it. Head-only, like the request id — degraded
    /// responses stay byte-identical in the body by the paper's
    /// forest↔DD equivalence.
    pub served_by: Option<&'static str>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            body: body.to_string_compact().into_bytes(),
            content_type: "application/json",
            retry_after_s: None,
            request_id: None,
            served_by: None,
        }
    }

    /// A JSON error response (`{"error": msg}`).
    pub fn error(status: u16, msg: impl Into<String>) -> Response {
        Response::json(status, &json::obj(vec![("error", json::s(msg.into()))]))
    }

    /// A `429 Too Many Requests` with the `Retry-After` contract.
    pub fn overloaded(retry_after_s: u32, msg: impl Into<String>) -> Response {
        let mut r = Response::error(429, msg);
        r.retry_after_s = Some(retry_after_s);
        r
    }

    /// Reason phrase for a status code.
    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            504 => "Gateway Timeout",
            _ => "Internal Server Error",
        }
    }

    /// Serialise head + body. `keep_alive` decides the `Connection`
    /// header — the caller owns connection policy, the response owns
    /// everything else.
    pub fn to_bytes(&self, keep_alive: bool) -> Vec<u8> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            Response::reason(self.status),
            self.content_type,
            self.body.len()
        );
        if let Some(s) = self.retry_after_s {
            head.push_str(&format!("Retry-After: {s}\r\n"));
        }
        if let Some(id) = &self.request_id {
            head.push_str(&format!("X-Request-Id: {id}\r\n"));
        }
        if let Some(backend) = self.served_by {
            head.push_str(&format!("X-Served-By: {backend}\r\n"));
        }
        head.push_str(if keep_alive {
            "Connection: keep-alive\r\n\r\n"
        } else {
            "Connection: close\r\n\r\n"
        });
        let mut out = head.into_bytes();
        out.extend_from_slice(&self.body);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push_str(p: &mut RequestParser, s: &str) {
        p.push(s.as_bytes());
    }

    #[test]
    fn parses_a_request_incrementally() {
        let mut p = RequestParser::new();
        push_str(&mut p, "POST /classify?backend=dd HTTP/1.1\r\nHost: x\r\n");
        assert!(p.try_next().unwrap().is_none(), "head incomplete");
        push_str(&mut p, "Content-Length: 4\r\nContent-Type: application/json\r\n\r\nab");
        assert!(p.try_next().unwrap().is_none(), "body incomplete");
        assert!(!p.is_idle());
        push_str(&mut p, "cd");
        let req = p.try_next().unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/classify");
        assert_eq!(req.query, "backend=dd");
        assert_eq!(req.param("backend"), Some("dd"));
        assert_eq!(req.param("model"), None);
        assert_eq!(req.content_type, "application/json");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.body, b"abcd");
        assert!(p.is_idle());
    }

    #[test]
    fn pipelined_requests_stay_buffered() {
        let mut p = RequestParser::new();
        push_str(
            &mut p,
            "GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n",
        );
        let first = p.try_next().unwrap().unwrap();
        assert_eq!(first.path, "/healthz");
        assert!(first.keep_alive);
        let second = p.try_next().unwrap().unwrap();
        assert_eq!(second.path, "/metrics");
        assert!(!second.keep_alive, "Connection: close wins");
        assert!(p.try_next().unwrap().is_none());
    }

    #[test]
    fn keep_alive_follows_http_version_defaults() {
        for (head, expect) in [
            ("GET / HTTP/1.0\r\n\r\n", false),
            ("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", true),
            ("GET / HTTP/1.1\r\n\r\n", true),
            ("GET / HTTP/1.1\r\nConnection: close\r\n\r\n", false),
        ] {
            let mut p = RequestParser::new();
            push_str(&mut p, head);
            let req = p.try_next().unwrap().unwrap();
            assert_eq!(req.keep_alive, expect, "head: {head:?}");
        }
    }

    #[test]
    fn malformed_heads_are_errors() {
        for head in [
            "\r\n\r\n",                                       // empty request line
            "GET\r\n\r\n",                                    // missing path
            "GET /\r\n\r\n",                                  // missing version
            "GET / HTTP/2\r\n\r\n",                           // unsupported version
            "GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n", // bad length
            "GET / HTTP/1.1\r\nContent-Length: 9999999\r\n\r\n", // over MAX_BODY
        ] {
            let mut p = RequestParser::new();
            push_str(&mut p, head);
            assert!(p.try_next().is_err(), "head must be rejected: {head:?}");
        }
    }

    #[test]
    fn oversized_head_rejected_before_terminator() {
        let mut p = RequestParser::new();
        push_str(&mut p, "GET / HTTP/1.1\r\n");
        p.push(&vec![b'a'; MAX_HEAD + 1]);
        assert!(p.try_next().is_err());
    }

    #[test]
    fn row_frame_roundtrip() {
        let cells = [1.0f32, -2.5, 3.25, f32::MIN, f32::MAX, 0.0];
        let m = RowMatrix::new(&cells, 3).unwrap();
        let frame = encode_rows(m).unwrap();
        assert_eq!(frame.len(), ROW_FRAME_HEADER + 24);
        let back = decode_rows(&frame).unwrap();
        assert_eq!(back.as_matrix(), m);
    }

    #[test]
    fn row_frame_nan_cells_accepted_by_policy() {
        let cells = [f32::NAN, 1.0];
        let frame = encode_rows(RowMatrix::new(&cells, 2).unwrap()).unwrap();
        let back = decode_rows(&frame).unwrap();
        assert!(back.as_matrix().row(0)[0].is_nan());
    }

    #[test]
    fn malformed_row_frames_table() {
        let good = encode_rows(RowMatrix::new(&[1.0f32, 2.0], 2).unwrap()).unwrap();
        // (name, frame bytes) — every case must be Err, never a panic
        let cases: Vec<(&str, Vec<u8>)> = vec![
            ("empty", vec![]),
            ("truncated header", good[..7].to_vec()),
            ("zero rows", {
                let mut f = good.clone();
                f[0..4].copy_from_slice(&0u32.to_le_bytes());
                f
            }),
            ("zero features", {
                let mut f = good.clone();
                f[4..8].copy_from_slice(&0u32.to_le_bytes());
                f
            }),
            ("row count overflow", {
                let mut f = good.clone();
                f[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
                f
            }),
            ("feature count overflow", {
                let mut f = good.clone();
                f[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
                f
            }),
            ("both dimensions overflow usize", {
                let mut f = good.clone();
                f[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
                f[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
                f
            }),
            ("body short of declared size", good[..good.len() - 1].to_vec()),
            ("body past declared size", {
                let mut f = good.clone();
                f.push(0);
                f
            }),
        ];
        for (name, frame) in cases {
            assert!(decode_rows(&frame).is_err(), "case '{name}' must be Err");
        }
        assert!(decode_rows(&good).is_ok(), "control case must decode");
    }

    #[test]
    fn response_serialises_with_framing_headers() {
        let r = Response::json(200, &json::obj(vec![("ok", Json::Bool(true))]));
        let text = String::from_utf8(r.to_bytes(true)).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
        let text = String::from_utf8(r.to_bytes(false)).unwrap();
        assert!(text.contains("Connection: close\r\n"));
    }

    #[test]
    fn request_id_header_is_captured_verbatim() {
        let mut p = RequestParser::new();
        push_str(
            &mut p,
            "GET /healthz HTTP/1.1\r\nX-Request-ID: Abc-123\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n",
        );
        let with_id = p.try_next().unwrap().unwrap();
        assert_eq!(with_id.request_id.as_deref(), Some("Abc-123"));
        let without = p.try_next().unwrap().unwrap();
        assert_eq!(without.request_id, None);
    }

    #[test]
    fn response_echoes_request_id_in_head_only() {
        let mut r = Response::json(200, &json::obj(vec![("ok", Json::Bool(true))]));
        r.request_id = Some("deadbeef00000001".to_string());
        let text = String::from_utf8(r.to_bytes(true)).unwrap();
        assert!(text.contains("X-Request-Id: deadbeef00000001\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"), "body untouched");
    }

    #[test]
    fn deadline_header_parses_leniently() {
        for (header, expect) in [
            ("X-Deadline-Ms: 250", Some(250)),
            ("x-deadline-ms: 1", Some(1)),
            ("X-Deadline-Ms: 0", None),    // zero reads as absent
            ("X-Deadline-Ms: nope", None), // garbled hint must not 400
            ("X-Deadline-Ms: -5", None),
            ("X-Unrelated: 250", None),
        ] {
            let mut p = RequestParser::new();
            push_str(&mut p, &format!("GET /healthz HTTP/1.1\r\n{header}\r\n\r\n"));
            let req = p.try_next().unwrap().unwrap();
            assert_eq!(req.deadline_ms, expect, "header: {header:?}");
        }
    }

    #[test]
    fn served_by_header_emits_in_head_only() {
        let mut r = Response::json(200, &json::obj(vec![("ok", Json::Bool(true))]));
        assert!(!String::from_utf8(r.to_bytes(true))
            .unwrap()
            .contains("X-Served-By"));
        r.served_by = Some("forest");
        let text = String::from_utf8(r.to_bytes(true)).unwrap();
        assert!(text.contains("X-Served-By: forest\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"), "body untouched");
    }

    #[test]
    fn fault_status_reasons_are_specific() {
        assert_eq!(Response::reason(500), "Internal Server Error");
        assert_eq!(Response::reason(503), "Service Unavailable");
        assert_eq!(Response::reason(504), "Gateway Timeout");
    }

    #[test]
    fn overloaded_response_carries_retry_after() {
        let r = Response::overloaded(1, "queue full");
        let text = String::from_utf8(r.to_bytes(true)).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("queue full"));
    }
}
