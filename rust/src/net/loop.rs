//! The evented serving front-end: event loop + acceptor + worker pool.
//!
//! One poller thread multiplexes the listener and every connection
//! (module [`conn`](crate::net::conn) state machines). Complete requests
//! are handed to a small worker pool through a *bounded* dispatch queue;
//! a full queue is answered immediately with `429` + `Retry-After`
//! (admission control — the loop never queues unboundedly, so a traffic
//! spike degrades into fast rejections instead of collapse). Workers run
//! the transport-independent handler and push `(token, response)`
//! completions back; a self-pipe waker interrupts the poll wait so
//! responses flush promptly.
//!
//! One request per connection is in flight at a time (read interest
//! drops while a worker owns the request) — pipelined bytes wait in the
//! parser and are served back-to-back after each response.

use crate::error::{Error, Result};
use crate::net::conn::{Conn, ConnState};
use crate::net::poll::{Event, Poller};
use crate::net::proto::{Request, Response};
use crate::net::LoopObserver;
use crate::obs::trace::{ReqTrace, Stage};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// The listener's registration token.
const TOK_LISTENER: u64 = 0;
/// The waker pipe's registration token.
const TOK_WAKER: u64 = 1;
/// First token handed to an accepted connection.
const TOK_FIRST_CONN: u64 = 2;

/// The transport-independent request handler (the serving layer's
/// `respond`, closed over its router). The trace rides along so the
/// handler can stamp its eval/serialize spans and honour inline-trace
/// requests.
pub type Handler = Arc<dyn Fn(&Request, &mut ReqTrace) -> Response + Send + Sync>;

/// A dispatched request: connection token, request, its trace.
type Job = (u64, Request, ReqTrace);

/// A finished request travelling back to the loop.
type Completion = (u64, Response, ReqTrace);

/// Event-loop policy.
#[derive(Debug, Clone)]
pub struct EventLoopConfig {
    /// Worker threads running the handler.
    pub workers: usize,
    /// Bounded dispatch-queue capacity: requests parsed while all
    /// workers are busy queue up to this depth, then shed with `429`.
    pub dispatch_cap: usize,
    /// Close connections with no socket activity for this long; a
    /// connection stalled *mid-request* gets `408` first.
    pub idle_timeout: Duration,
    /// `Retry-After` seconds on `429` responses.
    pub retry_after_s: u32,
    /// Per-connection pipelining cap: at most this many requests are
    /// admitted from one connection per pipelined burst; the next one is
    /// shed with `429` + `Retry-After` *before* the global dispatch
    /// queue is consulted, so one greedy client cannot crowd out the
    /// rest. `0` = unlimited.
    pub conn_max_inflight: usize,
}

impl Default for EventLoopConfig {
    fn default() -> Self {
        EventLoopConfig {
            workers: 4,
            dispatch_cap: 256,
            idle_timeout: Duration::from_secs(10),
            retry_after_s: 1,
            conn_max_inflight: 0,
        }
    }
}

/// Wakes the poll wait from any thread (self-pipe: one byte down a
/// nonblocking socketpair the loop watches).
#[derive(Clone)]
struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    fn wake(&self) {
        // a full pipe already guarantees a pending wakeup
        let _ = (&*self.tx).write(&[1]);
    }
}

/// A running event loop; `wake` + `join` after setting the shared
/// shutdown flag stops it.
pub struct EventLoopHandle {
    /// The bound address.
    pub addr: SocketAddr,
    waker: Waker,
    loop_thread: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl EventLoopHandle {
    /// Interrupt the poll wait (shutdown checks run on wakeup).
    pub fn wake(&self) {
        self.waker.wake();
    }

    /// Wake and join the loop and its workers (call after setting the
    /// shutdown flag passed to [`start`]).
    pub fn join(&mut self) {
        self.wake();
        if let Some(t) = self.loop_thread.take() {
            let _ = t.join();
        }
        for t in self.workers.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start the event loop on a bound listener. Returns once the poller is
/// armed; `shutdown` + [`EventLoopHandle::join`] stops everything.
pub fn start(
    listener: TcpListener,
    handler: Handler,
    observer: Arc<dyn LoopObserver>,
    cfg: EventLoopConfig,
    shutdown: Arc<AtomicBool>,
) -> Result<EventLoopHandle> {
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    let poller = Poller::new()?;
    let (wake_rx, wake_tx) = UnixStream::pair()?;
    wake_rx.set_nonblocking(true)?;
    wake_tx.set_nonblocking(true)?;
    poller.register(listener.as_raw_fd(), TOK_LISTENER, true, false)?;
    poller.register(wake_rx.as_raw_fd(), TOK_WAKER, true, false)?;
    let (dispatch_tx, dispatch_rx): (SyncSender<Job>, Receiver<Job>) =
        mpsc::sync_channel(cfg.dispatch_cap.max(1));
    let dispatch_rx = Arc::new(Mutex::new(dispatch_rx));
    let completions: Arc<Mutex<Vec<Completion>>> = Arc::new(Mutex::new(Vec::new()));
    let waker = Waker {
        tx: Arc::new(wake_tx),
    };
    let mut workers = Vec::with_capacity(cfg.workers.max(1));
    for w in 0..cfg.workers.max(1) {
        let rx = dispatch_rx.clone();
        let handler = handler.clone();
        let completions = completions.clone();
        let waker = waker.clone();
        let observer = observer.clone();
        workers.push(
            std::thread::Builder::new()
                .name(format!("net-worker-{w}"))
                .spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok((token, req, mut trace)) => {
                            observer.dispatch_dequeued();
                            trace.record(Stage::Queue);
                            let resp = handler(&req, &mut trace);
                            completions.lock().unwrap().push((token, resp, trace));
                            waker.wake();
                        }
                        Err(_) => return, // loop gone, queue drained
                    }
                })
                .map_err(|e| Error::Serve(format!("cannot spawn net worker: {e}")))?,
        );
    }
    let lp = Loop {
        poller,
        listener,
        wake_rx,
        conns: HashMap::new(),
        next_token: TOK_FIRST_CONN,
        dispatch_tx,
        completions,
        observer,
        cfg,
        shutdown,
    };
    let loop_thread = std::thread::Builder::new()
        .name("net-loop".into())
        .spawn(move || lp.run())
        .map_err(|e| Error::Serve(format!("cannot spawn event loop: {e}")))?;
    Ok(EventLoopHandle {
        addr,
        waker,
        loop_thread: Some(loop_thread),
        workers,
    })
}

struct Loop {
    poller: Poller,
    listener: TcpListener,
    wake_rx: UnixStream,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    dispatch_tx: SyncSender<Job>,
    completions: Arc<Mutex<Vec<Completion>>>,
    observer: Arc<dyn LoopObserver>,
    cfg: EventLoopConfig,
    shutdown: Arc<AtomicBool>,
}

impl Loop {
    fn run(mut self) {
        // the wait timeout doubles as the idle-sweep cadence
        let sweep = (self.cfg.idle_timeout / 4)
            .clamp(Duration::from_millis(25), Duration::from_millis(500));
        let mut events: Vec<Event> = Vec::new();
        while !self.shutdown.load(Ordering::Relaxed) {
            if let Err(e) = self.poller.wait(&mut events, Some(sweep)) {
                crate::log_warn!("net: poll wait failed: {e}");
                break;
            }
            for ev in &events {
                match ev.token {
                    TOK_LISTENER => self.accept_ready(),
                    TOK_WAKER => self.drain_waker(),
                    token => self.conn_ready(token, ev.readable, ev.writable),
                }
            }
            // completions may coalesce under one waker byte: drain every turn
            self.drain_completions();
            self.sweep_idle();
        }
        // orderly teardown: drop every connection (dispatch_tx drops with
        // self, which stops the workers once the queue drains)
        for token in self.conns.keys().copied().collect::<Vec<_>>() {
            self.close(token);
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue; // drop the stream; the client sees a reset
                    }
                    let _ = stream.set_nodelay(true);
                    let token = self.next_token;
                    self.next_token += 1;
                    if let Err(e) = self.poller.register(stream.as_raw_fd(), token, true, false) {
                        crate::log_warn!("net: cannot register connection: {e}");
                        continue;
                    }
                    self.conns.insert(token, Conn::new(stream));
                    self.observer.conn_opened();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    crate::log_warn!("net: accept error: {e}");
                    return;
                }
            }
        }
    }

    fn drain_waker(&mut self) {
        let mut buf = [0u8; 64];
        while matches!((&self.wake_rx).read(&mut buf), Ok(n) if n > 0) {}
    }

    fn conn_ready(&mut self, token: u64, readable: bool, writable: bool) {
        if writable {
            let (flushed, wrote) = {
                let Some(conn) = self.conns.get_mut(&token) else {
                    return;
                };
                let before = conn.bytes_written;
                let r = if conn.state == ConnState::Writing {
                    conn.flush()
                } else {
                    Ok(false)
                };
                (r, conn.bytes_written - before)
            };
            if wrote > 0 {
                self.observer.bytes_written(wrote);
            }
            match flushed {
                Ok(true) => {
                    if self.after_flush(token) {
                        self.advance(token);
                    }
                }
                Ok(false) => {}
                Err(_) => {
                    self.close(token);
                    return;
                }
            }
        }
        if readable {
            let (filled, read) = {
                let Some(conn) = self.conns.get_mut(&token) else {
                    return;
                };
                if conn.state != ConnState::Reading {
                    return; // bytes wait in the socket until this request is served
                }
                let before = conn.bytes_read;
                let r = conn.fill();
                (r, conn.bytes_read - before)
            };
            if read > 0 {
                self.observer.bytes_read(read);
            }
            match filled {
                Ok(_) => self.advance(token),
                Err(_) => self.close(token),
            }
        }
    }

    /// Parse-and-dispatch until the connection blocks: a dispatched
    /// request, a partial request, or a pending partial write.
    fn advance(&mut self, token: u64) {
        loop {
            // trace origin: the start of the *completing* parse call —
            // socket wait between fills never counts against a request
            let t_parse = Instant::now();
            let parsed = {
                let Some(conn) = self.conns.get_mut(&token) else {
                    return;
                };
                if conn.state != ConnState::Reading {
                    return;
                }
                conn.parser.try_next()
            };
            match parsed {
                Ok(Some(req)) => {
                    let keep = req.keep_alive;
                    let id = req
                        .request_id
                        .as_deref()
                        .map(crate::obs::trace::id_from_header)
                        .unwrap_or_else(crate::obs::trace::next_id);
                    let mut trace = ReqTrace::new_at(id, t_parse);
                    trace.record(Stage::Parse);
                    if let Some(conn) = self.conns.get_mut(&token) {
                        conn.keep_alive_pending = keep;
                    }
                    trace.record(Stage::Admission);
                    let burst = self.conns.get(&token).map_or(0, |c| c.burst);
                    if self.cfg.conn_max_inflight > 0 && burst >= self.cfg.conn_max_inflight {
                        // per-connection cap: shed without consulting the
                        // global dispatch queue
                        self.observer.request_rejected_conn();
                        let mut resp = Response::overloaded(
                            self.cfg.retry_after_s,
                            "connection pipelining cap reached — retry shortly",
                        );
                        resp.request_id = Some(
                            req.request_id
                                .unwrap_or_else(|| format!("{:016x}", trace.id)),
                        );
                        self.send_response(token, &resp, keep, Some(trace), false);
                        return;
                    }
                    match self.dispatch_tx.try_send((token, req, trace)) {
                        Ok(()) => {
                            self.observer.dispatch_enqueued();
                            if let Some(conn) = self.conns.get_mut(&token) {
                                conn.state = ConnState::InFlight;
                                conn.burst += 1;
                            }
                            // one request in flight per connection: no
                            // read interest until its response is out
                            self.set_interest(token, false, false);
                            return;
                        }
                        Err(TrySendError::Full((_, req, trace))) => {
                            // admission control: shed instead of queueing
                            self.observer.request_rejected();
                            let mut resp = Response::overloaded(
                                self.cfg.retry_after_s,
                                "server overloaded: dispatch queue full — retry shortly",
                            );
                            resp.request_id = Some(
                                req.request_id
                                    .unwrap_or_else(|| format!("{:016x}", trace.id)),
                            );
                            if !self.send_response(token, &resp, keep, Some(trace), false) {
                                return;
                            }
                            // flushed in full and still keep-alive: a
                            // pipelined request may already be buffered
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            self.close(token);
                            return;
                        }
                    }
                }
                Ok(None) => {
                    let eof = self
                        .conns
                        .get(&token)
                        .map(|c| c.peer_eof)
                        .unwrap_or(true);
                    if eof {
                        // no further bytes can complete a request
                        self.close(token);
                    }
                    return;
                }
                Err(e) => {
                    // malformed stream: error out and hang up
                    self.send_response(
                        token,
                        &Response::error(400, e.to_string()),
                        false,
                        None,
                        false,
                    );
                    return;
                }
            }
        }
    }

    /// Queue a response and flush optimistically. Returns true when it
    /// was fully flushed and the connection is back in `Reading`.
    /// `count_served` gates the latency observation (handler-completed
    /// requests only — sheds and protocol errors are counted separately).
    fn send_response(
        &mut self,
        token: u64,
        resp: &Response,
        keep_alive: bool,
        trace: Option<ReqTrace>,
        count_served: bool,
    ) -> bool {
        let (flushed, wrote) = {
            let Some(conn) = self.conns.get_mut(&token) else {
                return false;
            };
            conn.pending_trace = trace;
            conn.pending_served = count_served;
            // error responses hang up (the seed server's behaviour): the
            // client re-establishes state instead of guessing stream health
            let keep = keep_alive && !conn.peer_eof && resp.status < 400;
            conn.queue_response(resp, keep);
            let before = conn.bytes_written;
            let r = conn.flush();
            (r, conn.bytes_written - before)
        };
        if wrote > 0 {
            self.observer.bytes_written(wrote);
        }
        match flushed {
            Ok(true) => self.after_flush(token),
            Ok(false) => {
                self.set_interest(token, false, true);
                false
            }
            Err(_) => {
                self.close(token);
                false
            }
        }
    }

    /// Bookkeeping once a response is fully out: stamp the write span,
    /// commit the trace to the ring, record end-to-end latency, then
    /// close or rearm for reading. Returns true when the connection is
    /// readable again.
    fn after_flush(&mut self, token: u64) -> bool {
        let (close, trace, count, status) = {
            let Some(conn) = self.conns.get_mut(&token) else {
                return false;
            };
            conn.state = ConnState::Reading;
            if conn.parser.is_idle() {
                conn.burst = 0; // the pipelined burst has drained
            }
            (
                conn.close_after_write,
                conn.pending_trace.take(),
                conn.pending_served,
                conn.pending_status,
            )
        };
        if let Some(mut trace) = trace {
            trace.record(Stage::Write);
            let total_us = trace.commit(status);
            if count {
                self.observer
                    .request_served(Duration::from_micros(total_us));
            }
        }
        if close {
            self.close(token);
            return false;
        }
        self.set_interest(token, true, false);
        true
    }

    fn drain_completions(&mut self) {
        let done: Vec<Completion> = std::mem::take(&mut *self.completions.lock().unwrap());
        for (token, resp, trace) in done {
            let keep = match self.conns.get(&token) {
                Some(conn) => conn.keep_alive_pending,
                None => continue, // client vanished mid-flight
            };
            if self.send_response(token, &resp, keep, Some(trace), true) {
                self.advance(token);
            }
        }
    }

    fn sweep_idle(&mut self) {
        let now = Instant::now();
        let stale: Vec<(u64, bool)> = self
            .conns
            .iter()
            .filter(|(_, c)| {
                c.state != ConnState::InFlight
                    && now.duration_since(c.last_activity) > self.cfg.idle_timeout
            })
            .map(|(&t, c)| (t, c.state == ConnState::Reading && !c.parser.is_idle()))
            .collect();
        for (token, mid_request) in stale {
            if mid_request {
                // stalled mid-request: say why before hanging up
                self.send_response(
                    token,
                    &Response::error(408, "request read timed out"),
                    false,
                    None,
                    false,
                );
            }
            // idle-at-boundary (or still-unflushed 408): close silently
            if self.conns.contains_key(&token) {
                self.close(token);
            }
        }
    }

    fn set_interest(&mut self, token: u64, readable: bool, writable: bool) {
        if let Some(conn) = self.conns.get(&token) {
            if let Err(e) = self
                .poller
                .modify(conn.stream.as_raw_fd(), token, readable, writable)
            {
                crate::log_warn!("net: interest change failed: {e}");
            }
        }
    }

    fn close(&mut self, token: u64) {
        if let Some(conn) = self.conns.remove(&token) {
            self.poller.deregister(conn.stream.as_raw_fd());
            self.observer.conn_closed();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{self, Json};
    use std::net::TcpStream;
    use std::sync::atomic::AtomicUsize;

    #[derive(Default)]
    struct CountingObserver {
        opened: AtomicUsize,
        closed: AtomicUsize,
        served: AtomicUsize,
        rejected: AtomicUsize,
        conn_rejected: AtomicUsize,
    }

    impl LoopObserver for CountingObserver {
        fn conn_opened(&self) {
            self.opened.fetch_add(1, Ordering::Relaxed);
        }
        fn conn_closed(&self) {
            self.closed.fetch_add(1, Ordering::Relaxed);
        }
        fn request_served(&self, _latency: Duration) {
            self.served.fetch_add(1, Ordering::Relaxed);
        }
        fn request_rejected(&self) {
            self.rejected.fetch_add(1, Ordering::Relaxed);
        }
        fn request_rejected_conn(&self) {
            self.conn_rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Read exactly one HTTP response off a blocking stream.
    fn read_response(stream: &mut TcpStream) -> (u16, String, Vec<u8>) {
        use std::io::Read as _;
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut raw = Vec::new();
        let mut buf = [0u8; 1024];
        let head_end = loop {
            if let Some(i) = raw.windows(4).position(|w| w == b"\r\n\r\n") {
                break i;
            }
            let n = stream.read(&mut buf).expect("response head");
            assert!(n > 0, "EOF before response head");
            raw.extend_from_slice(&buf[..n]);
        };
        let head = String::from_utf8(raw[..head_end].to_vec()).unwrap();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .expect("status code");
        let content_length: usize = head
            .lines()
            .find_map(|l| {
                l.to_ascii_lowercase()
                    .strip_prefix("content-length:")
                    .map(str::to_string)
            })
            .and_then(|v| v.trim().parse().ok())
            .expect("content-length");
        let mut body = raw[head_end + 4..].to_vec();
        while body.len() < content_length {
            let n = stream.read(&mut buf).expect("response body");
            assert!(n > 0, "EOF mid-body");
            body.extend_from_slice(&buf[..n]);
        }
        (status, head, body)
    }

    fn send_request(stream: &mut TcpStream, path: &str, body: &[u8], close: bool) {
        let head = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
            body.len(),
            if close { "close" } else { "keep-alive" }
        );
        stream.write_all(head.as_bytes()).unwrap();
        stream.write_all(body).unwrap();
        stream.flush().unwrap();
    }

    fn echo_handler() -> Handler {
        Arc::new(|req: &Request, _trace: &mut ReqTrace| {
            Response::json(
                200,
                &json::obj(vec![
                    ("path", json::s(req.path.clone())),
                    ("len", json::num(req.body.len() as f64)),
                ]),
            )
        })
    }

    #[test]
    fn keep_alive_connection_serves_many_requests() {
        let observer = Arc::new(CountingObserver::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handle = start(
            TcpListener::bind("127.0.0.1:0").unwrap(),
            echo_handler(),
            observer.clone(),
            EventLoopConfig::default(),
            shutdown.clone(),
        )
        .unwrap();

        let mut client = TcpStream::connect(handle.addr).unwrap();
        for i in 0..3 {
            send_request(&mut client, &format!("/r{i}"), b"abc", false);
            let (status, head, body) = read_response(&mut client);
            assert_eq!(status, 200);
            assert!(head.contains("Connection: keep-alive"), "{head}");
            let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert_eq!(v.get_str("path"), Some(format!("/r{i}").as_str()));
            assert_eq!(v.get_i64("len"), Some(3));
        }
        // Connection: close is honoured after the final response
        send_request(&mut client, "/last", b"", true);
        let (status, head, _) = read_response(&mut client);
        assert_eq!(status, 200);
        assert!(head.contains("Connection: close"), "{head}");
        use std::io::Read as _;
        let mut rest = Vec::new();
        client.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "server must close after Connection: close");

        // wait for the close to be observed, then shut down
        let deadline = Instant::now() + Duration::from_secs(5);
        while observer.closed.load(Ordering::Relaxed) < 1 {
            assert!(Instant::now() < deadline, "close never observed");
            std::thread::sleep(Duration::from_millis(5));
        }
        shutdown.store(true, Ordering::Relaxed);
        handle.join();
        assert_eq!(observer.opened.load(Ordering::Relaxed), 1);
        assert_eq!(observer.closed.load(Ordering::Relaxed), 1);
        assert_eq!(observer.served.load(Ordering::Relaxed), 4);
        assert_eq!(observer.rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn malformed_request_gets_400_and_close() {
        let observer = Arc::new(CountingObserver::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handle = start(
            TcpListener::bind("127.0.0.1:0").unwrap(),
            echo_handler(),
            observer,
            EventLoopConfig::default(),
            shutdown.clone(),
        )
        .unwrap();
        let mut client = TcpStream::connect(handle.addr).unwrap();
        client.write_all(b"BOGUS\r\n\r\n").unwrap();
        let (status, head, _) = read_response(&mut client);
        assert_eq!(status, 400);
        assert!(head.contains("Connection: close"));
        shutdown.store(true, Ordering::Relaxed);
        handle.join();
    }

    #[test]
    fn full_dispatch_queue_sheds_with_429_retry_after() {
        let observer = Arc::new(CountingObserver::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let gate = Arc::new(AtomicBool::new(true));
        let entered = Arc::new(AtomicUsize::new(0));
        let handler: Handler = {
            let gate = gate.clone();
            let entered = entered.clone();
            Arc::new(move |_req: &Request, _trace: &mut ReqTrace| {
                entered.fetch_add(1, Ordering::SeqCst);
                while gate.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(2));
                }
                Response::json(200, &json::obj(vec![("ok", Json::Bool(true))]))
            })
        };
        let mut handle = start(
            TcpListener::bind("127.0.0.1:0").unwrap(),
            handler,
            observer.clone(),
            EventLoopConfig {
                workers: 1,
                dispatch_cap: 1,
                ..Default::default()
            },
            shutdown.clone(),
        )
        .unwrap();

        // A occupies the single worker…
        let mut a = TcpStream::connect(handle.addr).unwrap();
        send_request(&mut a, "/a", b"", false);
        let deadline = Instant::now() + Duration::from_secs(10);
        while entered.load(Ordering::SeqCst) < 1 {
            assert!(Instant::now() < deadline, "worker never picked up A");
            std::thread::sleep(Duration::from_millis(2));
        }
        // …B fills the depth-1 dispatch queue…
        let mut b = TcpStream::connect(handle.addr).unwrap();
        send_request(&mut b, "/b", b"", false);
        std::thread::sleep(Duration::from_millis(100)); // let the loop enqueue B
        // …so C must be shed immediately with the backpressure contract.
        let mut c = TcpStream::connect(handle.addr).unwrap();
        send_request(&mut c, "/c", b"", false);
        let (status, head, body) = read_response(&mut c);
        assert_eq!(status, 429, "head: {head}");
        assert!(head.contains("Retry-After: 1"), "{head}");
        assert!(
            head.contains("X-Request-Id: "),
            "sheds still carry a request id: {head}"
        );
        assert!(String::from_utf8_lossy(&body).contains("overloaded"));
        assert_eq!(observer.rejected.load(Ordering::Relaxed), 1);

        // opening the gate drains A then B with successes
        gate.store(false, Ordering::SeqCst);
        assert_eq!(read_response(&mut a).0, 200);
        assert_eq!(read_response(&mut b).0, 200);
        shutdown.store(true, Ordering::Relaxed);
        handle.join();
    }

    #[test]
    fn per_connection_pipelining_cap_sheds_with_429() {
        let observer = Arc::new(CountingObserver::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handle = start(
            TcpListener::bind("127.0.0.1:0").unwrap(),
            echo_handler(),
            observer.clone(),
            EventLoopConfig {
                conn_max_inflight: 2,
                ..Default::default()
            },
            shutdown.clone(),
        )
        .unwrap();

        // Pipeline four requests in ONE write syscall so the loop's first
        // fill buffers the whole burst in the parser before any dispatch
        // (the burst counter only resets once the parser drains).
        let mut client = TcpStream::connect(handle.addr).unwrap();
        let mut burst = Vec::new();
        for i in 0..4 {
            burst.extend_from_slice(
                format!(
                    "POST /p{i} HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\
                     Connection: keep-alive\r\n\r\n"
                )
                .as_bytes(),
            );
        }
        client.write_all(&burst).unwrap();
        client.flush().unwrap();

        // two requests fit the burst cap; the third is shed and hangs up
        assert_eq!(read_response(&mut client).0, 200);
        assert_eq!(read_response(&mut client).0, 200);
        let (status, head, _) = read_response(&mut client);
        assert_eq!(status, 429, "{head}");
        assert!(head.contains("Retry-After: 1"), "{head}");
        use std::io::Read as _;
        let mut rest = Vec::new();
        client.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "connection closes after the shed");
        assert_eq!(observer.conn_rejected.load(Ordering::Relaxed), 1);
        assert_eq!(
            observer.rejected.load(Ordering::Relaxed),
            0,
            "the global dispatch queue was never consulted"
        );
        shutdown.store(true, Ordering::Relaxed);
        handle.join();
    }

    #[test]
    fn stalled_mid_request_connection_gets_408() {
        let observer = Arc::new(CountingObserver::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handle = start(
            TcpListener::bind("127.0.0.1:0").unwrap(),
            echo_handler(),
            observer,
            EventLoopConfig {
                idle_timeout: Duration::from_millis(200),
                ..Default::default()
            },
            shutdown.clone(),
        )
        .unwrap();
        let mut client = TcpStream::connect(handle.addr).unwrap();
        // half a request, then silence
        client.write_all(b"POST /classify HTTP/1.1\r\nConte").unwrap();
        client.flush().unwrap();
        let t0 = Instant::now();
        let (status, _, _) = read_response(&mut client);
        assert_eq!(status, 408);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "timeout must fire promptly"
        );
        shutdown.store(true, Ordering::Relaxed);
        handle.join();
    }

    #[test]
    fn idle_connection_is_closed_silently() {
        let observer = Arc::new(CountingObserver::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handle = start(
            TcpListener::bind("127.0.0.1:0").unwrap(),
            echo_handler(),
            observer,
            EventLoopConfig {
                idle_timeout: Duration::from_millis(200),
                ..Default::default()
            },
            shutdown.clone(),
        )
        .unwrap();
        let mut client = TcpStream::connect(handle.addr).unwrap();
        client
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        use std::io::Read as _;
        let mut buf = Vec::new();
        client.read_to_end(&mut buf).unwrap(); // EOF, nothing written
        assert!(buf.is_empty(), "idle close sends no bytes");
        shutdown.store(true, Ordering::Relaxed);
        handle.join();
    }
}
