//! Nonblocking per-connection state machine for the evented front-end.
//!
//! A connection cycles `Reading → InFlight → Writing → Reading` for each
//! request it serves: the loop drains socket bytes into the incremental
//! parser, a complete request goes in flight to the worker pool (read
//! interest drops — one request per connection at a time keeps memory
//! bounded), the response is flushed incrementally under write
//! readiness, and a keep-alive connection returns to `Reading` (any
//! pipelined bytes already buffered in the parser are served next).

use crate::net::proto::{RequestParser, Response};
use crate::obs::trace::ReqTrace;
use crate::runtime::fault::{self, Point};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Lifecycle phase of a connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnState {
    /// Waiting for (more of) a request.
    Reading,
    /// A request is being handled by a worker; no socket interest.
    InFlight,
    /// A response is being flushed.
    Writing,
}

/// Outcome of draining the socket.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadOutcome {
    /// The peer is still connected (drained to `WouldBlock`).
    Open,
    /// The peer half-closed its write side (orderly EOF).
    Eof,
}

/// One nonblocking connection: socket + parser + pending write buffer.
#[derive(Debug)]
pub struct Conn {
    /// The nonblocking socket.
    pub stream: TcpStream,
    /// The incremental request parser (owns buffered request bytes).
    pub parser: RequestParser,
    /// Current lifecycle phase.
    pub state: ConnState,
    /// Close once the pending response is fully flushed.
    pub close_after_write: bool,
    /// The peer sent EOF; finish what is buffered, then close.
    pub peer_eof: bool,
    /// Keep-alive decision of the request currently in flight.
    pub keep_alive_pending: bool,
    /// Requests admitted from the current pipelined burst (cleared once
    /// the parser drains; the loop's per-connection cap compares this).
    pub burst: usize,
    /// Trace of the request in flight / being written: the loop stamps
    /// the write span and commits it to the trace ring after the flush.
    pub pending_trace: Option<ReqTrace>,
    /// Whether the pending response counts into the served-latency
    /// histogram (handler-completed requests; not sheds or 400s).
    pub pending_served: bool,
    /// Status of the pending response (stamped by
    /// [`Conn::queue_response`]; the trace commits with it).
    pub pending_status: u16,
    /// Lifetime bytes drained from this socket (the loop reports deltas
    /// to its observer after each [`Conn::fill`]).
    pub bytes_read: u64,
    /// Lifetime bytes flushed to this socket (delta-reported likewise).
    pub bytes_written: u64,
    /// Last socket activity (idle-timeout sweeps compare against this).
    pub last_activity: Instant,
    write_buf: Vec<u8>,
    written: usize,
}

impl Conn {
    /// Wrap an accepted socket (caller has already set nonblocking).
    pub fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            parser: RequestParser::new(),
            state: ConnState::Reading,
            close_after_write: false,
            peer_eof: false,
            keep_alive_pending: true,
            burst: 0,
            pending_trace: None,
            pending_served: false,
            pending_status: 200,
            bytes_read: 0,
            bytes_written: 0,
            last_activity: Instant::now(),
            write_buf: Vec::new(),
            written: 0,
        }
    }

    /// Drain everything the socket has into the parser (until
    /// `WouldBlock`). `Err` means the connection is broken and must be
    /// dropped.
    pub fn fill(&mut self) -> std::io::Result<ReadOutcome> {
        if fault::fires(Point::ConnReadErr) {
            return Err(std::io::Error::new(
                ErrorKind::ConnectionReset,
                "injected connection read error",
            ));
        }
        let mut buf = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    self.peer_eof = true;
                    return Ok(ReadOutcome::Eof);
                }
                Ok(n) => {
                    self.parser.push(&buf[..n]);
                    self.bytes_read += n as u64;
                    self.last_activity = Instant::now();
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(ReadOutcome::Open),
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }

    /// Queue a response for flushing and move to `Writing`.
    pub fn queue_response(&mut self, resp: &Response, keep_alive: bool) {
        self.write_buf = resp.to_bytes(keep_alive);
        self.written = 0;
        self.close_after_write = !keep_alive;
        self.pending_status = resp.status;
        self.state = ConnState::Writing;
    }

    /// Push pending response bytes (until `WouldBlock`). `Ok(true)` once
    /// everything is flushed; `Err` drops the connection.
    pub fn flush(&mut self) -> std::io::Result<bool> {
        while self.written < self.write_buf.len() {
            let mut end = self.write_buf.len();
            // Injected short write: offer only half the tail and report
            // "would block", exercising the Writing-state resumption the
            // caller re-arms write interest for. Never corrupts bytes.
            let short = end - self.written > 1 && fault::fires(Point::ConnWriteShort);
            if short {
                end = self.written + (end - self.written) / 2;
            }
            match self.stream.write(&self.write_buf[self.written..end]) {
                Ok(0) => {
                    return Err(std::io::Error::new(
                        ErrorKind::WriteZero,
                        "socket accepted no bytes",
                    ))
                }
                Ok(n) => {
                    self.written += n;
                    self.bytes_written += n as u64;
                    self.last_activity = Instant::now();
                    if short {
                        return Ok(false);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        self.write_buf.clear();
        self.written = 0;
        Ok(true)
    }

    /// True while response bytes await flushing.
    pub fn has_pending_write(&self) -> bool {
        self.written < self.write_buf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{self, Json};
    use std::io::{Read as _, Write as _};
    use std::net::TcpListener;
    use std::time::Duration;

    /// A connected (client, nonblocking server-side Conn) pair.
    fn pair() -> (TcpStream, Conn) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let client = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        (client, Conn::new(server))
    }

    #[test]
    fn reads_a_request_across_chunks_and_writes_the_response() {
        let (mut client, mut conn) = pair();
        client
            .write_all(b"POST /classify HTTP/1.1\r\nContent-Le")
            .unwrap();
        client.flush().unwrap();
        // wait until the first chunk is visible server-side
        let deadline = Instant::now() + Duration::from_secs(5);
        while conn.parser.is_idle() {
            assert_eq!(conn.fill().unwrap(), ReadOutcome::Open);
            assert!(Instant::now() < deadline, "first chunk never arrived");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(conn.parser.try_next().unwrap().is_none(), "incomplete");
        client.write_all(b"ngth: 2\r\n\r\nhi").unwrap();
        client.flush().unwrap();
        let req = loop {
            conn.fill().unwrap();
            if let Some(req) = conn.parser.try_next().unwrap() {
                break req;
            }
            assert!(Instant::now() < deadline, "request never completed");
            std::thread::sleep(Duration::from_millis(1));
        };
        assert_eq!(req.body, b"hi");
        assert!(req.keep_alive);
        let wire_len = "POST /classify HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi".len();
        assert_eq!(conn.bytes_read, wire_len as u64, "every wire byte counted");

        let resp = Response::json(200, &json::obj(vec![("ok", Json::Bool(true))]));
        conn.queue_response(&resp, true);
        assert_eq!(conn.state, ConnState::Writing);
        assert!(conn.has_pending_write());
        assert!(conn.flush().unwrap(), "small response flushes at once");
        assert!(!conn.has_pending_write());
        assert_eq!(conn.bytes_written, resp.to_bytes(true).len() as u64);

        client
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut got = vec![0u8; 256];
        let n = client.read(&mut got).unwrap();
        let text = String::from_utf8_lossy(&got[..n]).to_string();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive"));
    }

    #[test]
    fn detects_peer_eof() {
        let (client, mut conn) = pair();
        drop(client);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match conn.fill().unwrap() {
                ReadOutcome::Eof => break,
                ReadOutcome::Open => {
                    assert!(Instant::now() < deadline, "EOF never surfaced");
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        assert!(conn.peer_eof);
        assert!(conn.parser.is_idle());
    }
}
