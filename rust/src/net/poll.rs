//! Readiness polling, std-only (raw `epoll(7)` / `kqueue(2)` FFI).
//!
//! No async runtime or polling crate is available offline, so the two
//! syscall families are declared here directly, in the style of
//! [`crate::runtime::mmap`]: a deliberately tiny, level-triggered
//! surface (register / modify / deregister / wait) behind one portable
//! type. Tokens are opaque `u64`s chosen by the caller and returned
//! verbatim with each event.
//!
//! Linux uses epoll; macOS uses kqueue (gated to macOS only — other BSDs
//! lay out `struct kevent` differently, and declaring a struct we cannot
//! test would be a silent ABI hazard). Everywhere else
//! [`supported`] reports `false` and the serving layer falls back to the
//! sync thread-per-connection front-end.

/// Whether this build has a readiness poller (and therefore the evented
/// serving front-end). When `false`, `serve --io auto` resolves to the
/// sync fallback and `--io evented` is a configuration error.
pub const fn supported() -> bool {
    cfg!(any(
        target_os = "linux",
        all(target_os = "macos", target_pointer_width = "64")
    ))
}

/// One readiness event: the registration token plus the directions that
/// are ready. Error/hangup conditions surface as readable+writable so
/// the owning connection performs I/O and observes the failure directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// The caller-chosen registration token.
    pub token: u64,
    /// The fd can be read without blocking (or has hung up).
    pub readable: bool,
    /// The fd can be written without blocking (or has errored).
    pub writable: bool,
}

#[cfg(target_os = "linux")]
mod imp {
    use super::Event;
    use crate::error::{Error, Result};
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0x80000;
    const EINTR: i32 = 4;

    /// `struct epoll_event`: packed on x86-64 (the kernel ABI), natural
    /// alignment elsewhere.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    fn os_err(call: &str) -> Error {
        Error::Serve(format!("{call}: {}", std::io::Error::last_os_error()))
    }

    /// A level-triggered epoll instance, closed on drop.
    pub struct Poller {
        epfd: c_int,
    }

    impl Poller {
        /// A fresh poller.
        pub fn new() -> Result<Poller> {
            // SAFETY: plain syscall, no pointers.
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(os_err("epoll_create1"));
            }
            Ok(Poller { epfd })
        }

        fn ctl(&self, op: c_int, fd: RawFd, token: u64, readable: bool, writable: bool) -> c_int {
            let mut ev = EpollEvent {
                events: (if readable { EPOLLIN } else { 0 })
                    | (if writable { EPOLLOUT } else { 0 }),
                data: token,
            };
            // SAFETY: `ev` is a live stack value for the duration of the
            // call; the kernel copies it before returning.
            unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) }
        }

        /// Start watching `fd` with the given interest.
        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            if self.ctl(EPOLL_CTL_ADD, fd, token, readable, writable) < 0 {
                return Err(os_err("epoll_ctl(ADD)"));
            }
            Ok(())
        }

        /// Change the interest set of a registered fd.
        pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
            if self.ctl(EPOLL_CTL_MOD, fd, token, readable, writable) < 0 {
                return Err(os_err("epoll_ctl(MOD)"));
            }
            Ok(())
        }

        /// Stop watching `fd`. Best-effort: closing an fd drops its
        /// registration anyway, so failures are ignored.
        pub fn deregister(&self, fd: RawFd) {
            let _ = self.ctl(EPOLL_CTL_DEL, fd, 0, false, false);
        }

        /// Wait for events (`None` = block indefinitely), appending them
        /// to `out` (cleared first). EINTR retries transparently.
        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> Result<()> {
            out.clear();
            let mut evs = [EpollEvent { events: 0, data: 0 }; 256];
            // round up so sub-millisecond timeouts never busy-spin
            let ms: c_int = match timeout {
                None => -1,
                Some(d) => d.as_nanos().div_ceil(1_000_000).min(i32::MAX as u128) as c_int,
            };
            let n = loop {
                // SAFETY: `evs` is a live buffer of 256 entries and the
                // length passed matches.
                let n = unsafe { epoll_wait(self.epfd, evs.as_mut_ptr(), evs.len() as c_int, ms) };
                if n >= 0 {
                    break n as usize;
                }
                if std::io::Error::last_os_error().raw_os_error() != Some(EINTR) {
                    return Err(os_err("epoll_wait"));
                }
            };
            for ev in &evs[..n] {
                // copy out of the (possibly packed) struct before use
                let events = { ev.events };
                let token = { ev.data };
                out.push(Event {
                    token,
                    readable: events & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: events & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: epfd is the live descriptor created in `new`.
            let _ = unsafe { close(self.epfd) };
        }
    }

    impl std::fmt::Debug for Poller {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Poller(epoll fd {})", self.epfd)
        }
    }
}

#[cfg(all(target_os = "macos", target_pointer_width = "64"))]
mod imp {
    use super::Event;
    use crate::error::{Error, Result};
    use std::os::raw::{c_int, c_void};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EVFILT_READ: i16 = -1;
    const EVFILT_WRITE: i16 = -2;
    const EV_ADD: u16 = 0x0001;
    const EV_DELETE: u16 = 0x0002;
    const EV_ENABLE: u16 = 0x0004;
    const EV_DISABLE: u16 = 0x0008;
    const EV_ERROR: u16 = 0x4000;
    const EINTR: i32 = 4;

    /// `struct kevent` on 64-bit macOS.
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct KEvent {
        ident: usize,
        filter: i16,
        flags: u16,
        fflags: u32,
        data: isize,
        udata: *mut c_void,
    }

    #[repr(C)]
    struct Timespec {
        tv_sec: i64,
        tv_nsec: i64,
    }

    extern "C" {
        fn kqueue() -> c_int;
        fn kevent(
            kq: c_int,
            changelist: *const KEvent,
            nchanges: c_int,
            eventlist: *mut KEvent,
            nevents: c_int,
            timeout: *const Timespec,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    fn os_err(call: &str) -> Error {
        Error::Serve(format!("{call}: {}", std::io::Error::last_os_error()))
    }

    fn change(fd: RawFd, filter: i16, flags: u16, token: u64) -> KEvent {
        KEvent {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: token as *mut c_void,
        }
    }

    /// A level-triggered kqueue instance, closed on drop. Both filters
    /// are always added (one enabled, one disabled), so interest changes
    /// are pure enable/disable toggles and deletes never race ENOENT.
    pub struct Poller {
        kq: c_int,
    }

    // SAFETY: `KEvent::udata` is only ever a token in disguise; the
    // poller itself holds nothing but the kqueue descriptor.
    unsafe impl Send for Poller {}
    unsafe impl Sync for Poller {}

    impl Poller {
        /// A fresh poller.
        pub fn new() -> Result<Poller> {
            // SAFETY: plain syscall, no pointers.
            let kq = unsafe { kqueue() };
            if kq < 0 {
                return Err(os_err("kqueue"));
            }
            Ok(Poller { kq })
        }

        fn apply(&self, changes: &[KEvent], call: &str) -> Result<()> {
            // SAFETY: `changes` is a live slice; no eventlist is passed.
            let rc = unsafe {
                kevent(
                    self.kq,
                    changes.as_ptr(),
                    changes.len() as c_int,
                    std::ptr::null_mut(),
                    0,
                    std::ptr::null(),
                )
            };
            if rc < 0 {
                return Err(os_err(call));
            }
            Ok(())
        }

        /// Start watching `fd` with the given interest.
        pub fn register(
            &self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            let r = if readable { EV_ENABLE } else { EV_DISABLE };
            let w = if writable { EV_ENABLE } else { EV_DISABLE };
            self.apply(
                &[
                    change(fd, EVFILT_READ, EV_ADD | r, token),
                    change(fd, EVFILT_WRITE, EV_ADD | w, token),
                ],
                "kevent(ADD)",
            )
        }

        /// Change the interest set of a registered fd.
        pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
            self.register(fd, token, readable, writable)
        }

        /// Stop watching `fd`. Best-effort, as with epoll.
        pub fn deregister(&self, fd: RawFd) {
            let _ = self.apply(
                &[
                    change(fd, EVFILT_READ, EV_DELETE, 0),
                    change(fd, EVFILT_WRITE, EV_DELETE, 0),
                ],
                "kevent(DELETE)",
            );
        }

        /// Wait for events (`None` = block indefinitely), appending them
        /// to `out` (cleared first). EINTR retries transparently.
        pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> Result<()> {
            out.clear();
            let mut evs = [change(0, 0, 0, 0); 256];
            let ts = timeout.map(|d| Timespec {
                tv_sec: d.as_secs().min(i64::MAX as u64) as i64,
                tv_nsec: d.subsec_nanos() as i64,
            });
            let ts_ptr = ts
                .as_ref()
                .map(|t| t as *const Timespec)
                .unwrap_or(std::ptr::null());
            let n = loop {
                // SAFETY: `evs` is a live buffer of 256 entries, the
                // length matches, and `ts_ptr` outlives the call.
                let n = unsafe {
                    kevent(
                        self.kq,
                        std::ptr::null(),
                        0,
                        evs.as_mut_ptr(),
                        evs.len() as c_int,
                        ts_ptr,
                    )
                };
                if n >= 0 {
                    break n as usize;
                }
                if std::io::Error::last_os_error().raw_os_error() != Some(EINTR) {
                    return Err(os_err("kevent(wait)"));
                }
            };
            for ev in &evs[..n] {
                let errored = ev.flags & EV_ERROR != 0;
                out.push(Event {
                    token: ev.udata as u64,
                    readable: ev.filter == EVFILT_READ || errored,
                    writable: ev.filter == EVFILT_WRITE || errored,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            // SAFETY: kq is the live descriptor created in `new`.
            let _ = unsafe { close(self.kq) };
        }
    }

    impl std::fmt::Debug for Poller {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            write!(f, "Poller(kqueue fd {})", self.kq)
        }
    }
}

#[cfg(any(target_os = "linux", all(target_os = "macos", target_pointer_width = "64")))]
pub use imp::Poller;

#[cfg(all(
    test,
    any(target_os = "linux", all(target_os = "macos", target_pointer_width = "64"))
))]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;
    use std::time::Duration;

    #[test]
    fn reports_listener_and_stream_readiness() {
        assert!(supported());
        let poller = Poller::new().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        poller.register(listener.as_raw_fd(), 7, true, false).unwrap();

        // idle poll times out with no events
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.is_empty(), "no readiness before a client connects");

        // a connecting client makes the listener readable
        let mut client = TcpStream::connect(addr).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(
            events.iter().any(|e| e.token == 7 && e.readable),
            "listener must report readable: {events:?}"
        );
        let (server_side, _) = listener.accept().unwrap();

        // a connected stream is immediately writable; readable once the
        // peer sends bytes
        poller.register(server_side.as_raw_fd(), 9, true, true).unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 9 && e.writable));
        client.write_all(b"ping").unwrap();
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            poller.wait(&mut events, Some(Duration::from_millis(100))).unwrap();
            if events.iter().any(|e| e.token == 9 && e.readable) {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "stream never readable");
        }

        // dropping write interest stops writable events (level-triggered:
        // an idle readable-only stream with drained input reports nothing)
        let mut buf = [0u8; 8];
        use std::io::Read;
        server_side.set_nonblocking(true).unwrap();
        let _ = (&server_side).read(&mut buf);
        poller.modify(server_side.as_raw_fd(), 9, true, false).unwrap();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert!(
            !events.iter().any(|e| e.token == 9 && e.writable),
            "write interest was dropped: {events:?}"
        );
        poller.deregister(server_side.as_raw_fd());
        poller.wait(&mut events, Some(Duration::from_millis(20))).unwrap();
        assert!(events.iter().all(|e| e.token != 9), "deregistered fd is silent");
    }

    #[test]
    fn self_pipe_wakeup() {
        // the event loop's waker: one end registered, the other written
        // from any thread to interrupt a blocking wait
        let poller = Poller::new().unwrap();
        let (rx, tx) = std::os::unix::net::UnixStream::pair().unwrap();
        rx.set_nonblocking(true).unwrap();
        poller.register(rx.as_raw_fd(), 1, true, false).unwrap();
        let waker = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            (&tx).write_all(&[1]).unwrap();
            tx // keep the write end alive past the wait
        });
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable));
        let _tx = waker.join().unwrap();
    }
}
