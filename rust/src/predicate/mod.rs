//! Predicates and the global variable order.
//!
//! Every split in every tree is a threshold predicate `x[feature] < threshold`.
//! The ADD machinery requires a **fixed total order** on predicates (§3.2:
//! "they enforce an order of predicates along all paths"); this module
//! interns all predicates occurring in a forest into a [`PredicatePool`]
//! whose index *is* the ADD level.
//!
//! Two orders are provided (the choice is a classical BDD quality lever the
//! paper defers to "the corresponding frameworks"; `ablation_cadence` benches
//! both):
//! - [`PredicateOrder::FeatureThreshold`]: lexicographic by `(feature,
//!   threshold)`. Keeps all predicates of one feature adjacent and sorted.
//! - [`PredicateOrder::FrequencyDesc`]: most-used predicates first (a
//!   greedy static heuristic in the spirit of common BDD ordering
//!   heuristics). Measured best on all six evaluation datasets — smaller
//!   diagrams, fewer steps, faster compiles (ablation_order bench) — and
//!   therefore the compiler default.

use crate::data::{FeatureKind, Schema};
use crate::forest::RandomForest;
use crate::tree::TreeNode;
use std::collections::HashMap;

/// An atomic decision `x[feature] < threshold`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Predicate {
    /// Feature column index.
    pub feature: u32,
    /// Strict upper-bound threshold.
    pub threshold: f32,
}

impl Predicate {
    fn key(&self) -> (u32, u32) {
        (self.feature, self.threshold.to_bits())
    }
}

/// Variable-order heuristic for the pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PredicateOrder {
    /// Sort by `(feature, threshold)`.
    FeatureThreshold,
    /// Sort by occurrence count (descending), ties by `(feature,
    /// threshold)` — the measured-best default.
    #[default]
    FrequencyDesc,
}

/// The value domain of a feature, used by feasibility reasoning.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Domain {
    /// Real-valued feature.
    Real,
    /// Values lie on the integer grid `0..cardinality` (ordinal-encoded
    /// categorical features).
    Grid {
        /// Number of admissible integer values.
        cardinality: u32,
    },
}

/// Interned, totally ordered predicate set of one compilation.
#[derive(Debug, Clone)]
pub struct PredicatePool {
    preds: Vec<Predicate>,
    index: HashMap<(u32, u32), u32>,
    domains: Vec<Domain>,
    n_features: usize,
}

impl PredicatePool {
    /// Build a pool from an explicit predicate list (tests, tools). The
    /// list order becomes the variable order; duplicates are rejected by
    /// debug assertion.
    pub fn from_predicates(
        preds: Vec<Predicate>,
        domains: Vec<Domain>,
        n_features: usize,
    ) -> PredicatePool {
        let index: HashMap<(u32, u32), u32> = preds
            .iter()
            .enumerate()
            .map(|(i, p)| (p.key(), i as u32))
            .collect();
        debug_assert_eq!(index.len(), preds.len(), "duplicate predicates");
        debug_assert_eq!(domains.len(), n_features);
        PredicatePool {
            preds,
            index,
            domains,
            n_features,
        }
    }

    /// Collect and order every predicate of `forest`.
    pub fn from_forest(forest: &RandomForest, order: PredicateOrder) -> PredicatePool {
        let mut counts: HashMap<(u32, u32), (Predicate, usize)> = HashMap::new();
        for tree in &forest.trees {
            for node in &tree.nodes {
                if let TreeNode::Split {
                    feature, threshold, ..
                } = node
                {
                    let p = Predicate {
                        feature: *feature,
                        threshold: *threshold,
                    };
                    counts.entry(p.key()).or_insert((p, 0)).1 += 1;
                }
            }
        }
        let mut preds: Vec<(Predicate, usize)> = counts.into_values().collect();
        match order {
            PredicateOrder::FeatureThreshold => preds.sort_by(|a, b| {
                (a.0.feature, a.0.threshold)
                    .partial_cmp(&(b.0.feature, b.0.threshold))
                    .unwrap()
            }),
            PredicateOrder::FrequencyDesc => preds.sort_by(|a, b| {
                b.1.cmp(&a.1).then(
                    (a.0.feature, a.0.threshold)
                        .partial_cmp(&(b.0.feature, b.0.threshold))
                        .unwrap(),
                )
            }),
        }
        let preds: Vec<Predicate> = preds.into_iter().map(|(p, _)| p).collect();
        let index = preds
            .iter()
            .enumerate()
            .map(|(i, p)| (p.key(), i as u32))
            .collect();
        PredicatePool {
            preds,
            index,
            domains: Self::domains_from_schema(&forest.schema),
            n_features: forest.schema.n_features(),
        }
    }

    fn domains_from_schema(schema: &Schema) -> Vec<Domain> {
        schema
            .features
            .iter()
            .map(|f| match &f.kind {
                FeatureKind::Numeric => Domain::Real,
                FeatureKind::Categorical { values } => Domain::Grid {
                    cardinality: values.len() as u32,
                },
            })
            .collect()
    }

    /// Number of predicates (= number of ADD levels).
    pub fn len(&self) -> usize {
        self.preds.len()
    }

    /// True when the pool is empty (forest of single-leaf trees).
    pub fn is_empty(&self) -> bool {
        self.preds.is_empty()
    }

    /// Predicate at a level.
    pub fn pred(&self, level: u32) -> Predicate {
        self.preds[level as usize]
    }

    /// Level of a predicate (must have been collected).
    pub fn level_of(&self, feature: u32, threshold: f32) -> Option<u32> {
        self.index.get(&(feature, threshold.to_bits())).copied()
    }

    /// Evaluate the predicate at `level` on a row.
    #[inline]
    pub fn holds(&self, level: u32, x: &[f32]) -> bool {
        let p = self.preds[level as usize];
        x[p.feature as usize] < p.threshold
    }

    /// Feature domains (for feasibility reasoning).
    pub fn domains(&self) -> &[Domain] {
        &self.domains
    }

    /// Number of feature columns.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Render a predicate like the paper's figures (`petalwidth < 1.65`).
    pub fn render(&self, level: u32, schema: &Schema) -> String {
        let p = self.pred(level);
        format!(
            "{} < {}",
            schema.features[p.feature as usize].name, p.threshold
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::datasets;
    use crate::forest::ForestLearner;

    fn small_forest() -> RandomForest {
        ForestLearner::default()
            .trees(8)
            .seed(1)
            .fit(&datasets::iris())
    }

    #[test]
    fn collects_all_split_predicates() {
        let f = small_forest();
        let pool = PredicatePool::from_forest(&f, PredicateOrder::FeatureThreshold);
        assert!(!pool.is_empty());
        for tree in &f.trees {
            for node in &tree.nodes {
                if let TreeNode::Split {
                    feature, threshold, ..
                } = node
                {
                    assert!(pool.level_of(*feature, *threshold).is_some());
                }
            }
        }
    }

    #[test]
    fn feature_threshold_order_is_sorted() {
        let pool = PredicatePool::from_forest(&small_forest(), PredicateOrder::FeatureThreshold);
        for w in 0..pool.len() - 1 {
            let a = pool.pred(w as u32);
            let b = pool.pred(w as u32 + 1);
            assert!(
                (a.feature, a.threshold) < (b.feature, b.threshold),
                "{a:?} !< {b:?}"
            );
        }
    }

    #[test]
    fn frequency_order_puts_popular_first() {
        let f = small_forest();
        let pool = PredicatePool::from_forest(&f, PredicateOrder::FrequencyDesc);
        // count occurrences of level 0's predicate vs the last level's
        let count = |p: Predicate| {
            f.trees
                .iter()
                .flat_map(|t| &t.nodes)
                .filter(|n| {
                    matches!(n, TreeNode::Split { feature, threshold, .. }
                        if *feature == p.feature && *threshold == p.threshold)
                })
                .count()
        };
        let first = count(pool.pred(0));
        let last = count(pool.pred(pool.len() as u32 - 1));
        assert!(first >= last);
    }

    #[test]
    fn holds_matches_semantics() {
        let f = small_forest();
        let pool = PredicatePool::from_forest(&f, PredicateOrder::FeatureThreshold);
        let p = pool.pred(0);
        let mut x = vec![0.0f32; 4];
        x[p.feature as usize] = p.threshold - 0.1;
        assert!(pool.holds(0, &x));
        x[p.feature as usize] = p.threshold;
        assert!(!pool.holds(0, &x));
    }

    #[test]
    fn domains_follow_schema() {
        let iris_pool =
            PredicatePool::from_forest(&small_forest(), PredicateOrder::FeatureThreshold);
        assert!(iris_pool.domains().iter().all(|d| *d == Domain::Real));
        let ttt = ForestLearner::default()
            .trees(3)
            .seed(0)
            .fit(&datasets::tic_tac_toe());
        let pool = PredicatePool::from_forest(&ttt, PredicateOrder::FeatureThreshold);
        assert!(pool
            .domains()
            .iter()
            .all(|d| *d == Domain::Grid { cardinality: 3 }));
    }

    #[test]
    fn render_uses_feature_names() {
        let f = small_forest();
        let pool = PredicatePool::from_forest(&f, PredicateOrder::FeatureThreshold);
        let text = pool.render(0, &f.schema);
        assert!(text.contains(" < "));
        assert!(text.starts_with("sepallength"));
    }
}
