//! Decision trees: structure, evaluation, serialization.
//!
//! Trees are stored as flat arenas (`Vec<TreeNode>`, root at index 0).
//! The split convention throughout the system is the paper's: the predicate
//! `x[feature] < threshold` routes **left** when true, right otherwise.

pub mod learner;

pub use learner::{TreeLearner, TreeParams};

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// One node of a decision tree.
#[derive(Debug, Clone, PartialEq)]
pub enum TreeNode {
    /// Terminal: predicts a class index.
    Leaf {
        /// Predicted class index.
        class: u32,
    },
    /// Internal: tests `x[feature] < threshold`.
    Split {
        /// Feature column tested.
        feature: u32,
        /// Threshold; `<` goes left, `>=` goes right.
        threshold: f32,
        /// Arena index of the `<` child.
        left: u32,
        /// Arena index of the `>=` child.
        right: u32,
    },
}

/// A decision tree over `n_features` columns predicting one of `n_classes`.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionTree {
    /// Flat node arena; index 0 is the root.
    pub nodes: Vec<TreeNode>,
    /// Number of feature columns the tree may test.
    pub n_features: usize,
    /// Number of classes in the co-domain.
    pub n_classes: usize,
}

impl DecisionTree {
    /// A single-leaf tree.
    pub fn leaf(class: u32, n_features: usize, n_classes: usize) -> DecisionTree {
        DecisionTree {
            nodes: vec![TreeNode::Leaf { class }],
            n_features,
            n_classes,
        }
    }

    /// Total node count (internal + leaves).
    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Leaf count.
    pub fn n_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, TreeNode::Leaf { .. }))
            .count()
    }

    /// Maximum root-to-leaf depth (a single leaf has depth 0).
    pub fn depth(&self) -> usize {
        fn go(tree: &DecisionTree, i: u32) -> usize {
            match tree.nodes[i as usize] {
                TreeNode::Leaf { .. } => 0,
                TreeNode::Split { left, right, .. } => 1 + go(tree, left).max(go(tree, right)),
            }
        }
        go(self, 0)
    }

    /// Predict the class of one row.
    pub fn predict(&self, x: &[f32]) -> u32 {
        self.walk(x).0
    }

    /// Predict and count the steps taken (internal nodes visited) — the
    /// paper's §6 cost metric for tree structures.
    pub fn walk(&self, x: &[f32]) -> (u32, usize) {
        debug_assert_eq!(x.len(), self.n_features);
        let mut i = 0u32;
        let mut steps = 0usize;
        loop {
            match self.nodes[i as usize] {
                TreeNode::Leaf { class } => return (class, steps),
                TreeNode::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    steps += 1;
                    i = if x[feature as usize] < threshold {
                        left
                    } else {
                        right
                    };
                }
            }
        }
    }

    /// Structural validation: indices in range, no cycles, all nodes
    /// reachable, feature/class indices within bounds.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            return Err(Error::invalid("tree has no nodes"));
        }
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![0u32];
        while let Some(i) = stack.pop() {
            let idx = i as usize;
            if idx >= self.nodes.len() {
                return Err(Error::invalid(format!("child index {i} out of range")));
            }
            if seen[idx] {
                return Err(Error::invalid(format!("node {i} reachable twice (not a tree)")));
            }
            seen[idx] = true;
            match self.nodes[idx] {
                TreeNode::Leaf { class } => {
                    if class as usize >= self.n_classes {
                        return Err(Error::invalid(format!("leaf class {class} out of range")));
                    }
                }
                TreeNode::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    if feature as usize >= self.n_features {
                        return Err(Error::invalid(format!("feature {feature} out of range")));
                    }
                    if !threshold.is_finite() {
                        return Err(Error::invalid("non-finite threshold"));
                    }
                    stack.push(left);
                    stack.push(right);
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(Error::invalid("unreachable nodes in arena"));
        }
        Ok(())
    }

    /// JSON encoding (model persistence).
    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| match n {
                TreeNode::Leaf { class } => json::obj(vec![("leaf", json::num(*class as f64))]),
                TreeNode::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => json::obj(vec![
                    ("f", json::num(*feature as f64)),
                    ("t", json::num(*threshold as f64)),
                    ("l", json::num(*left as f64)),
                    ("r", json::num(*right as f64)),
                ]),
            })
            .collect();
        json::obj(vec![
            ("n_features", json::num(self.n_features as f64)),
            ("n_classes", json::num(self.n_classes as f64)),
            ("nodes", Json::Arr(nodes)),
        ])
    }

    /// JSON decoding (validates the result).
    pub fn from_json(v: &Json) -> Result<DecisionTree> {
        let n_features = v
            .get_i64("n_features")
            .ok_or_else(|| Error::parse("tree: missing n_features"))? as usize;
        let n_classes = v
            .get_i64("n_classes")
            .ok_or_else(|| Error::parse("tree: missing n_classes"))? as usize;
        let nodes_json = v
            .get("nodes")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::parse("tree: missing nodes"))?;
        let mut nodes = Vec::with_capacity(nodes_json.len());
        for nj in nodes_json {
            if let Some(c) = nj.get_i64("leaf") {
                nodes.push(TreeNode::Leaf { class: c as u32 });
            } else {
                let f = nj.get_i64("f").ok_or_else(|| Error::parse("tree node: missing f"))?;
                let t = nj
                    .get("t")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| Error::parse("tree node: missing t"))?;
                let l = nj.get_i64("l").ok_or_else(|| Error::parse("tree node: missing l"))?;
                let r = nj.get_i64("r").ok_or_else(|| Error::parse("tree node: missing r"))?;
                nodes.push(TreeNode::Split {
                    feature: f as u32,
                    threshold: t as f32,
                    left: l as u32,
                    right: r as u32,
                });
            }
        }
        let tree = DecisionTree {
            nodes,
            n_features,
            n_classes,
        };
        tree.validate()?;
        Ok(tree)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// x0 < 1.0 ? c0 : (x1 < -2.0 ? c1 : c2)
    pub(crate) fn sample_tree() -> DecisionTree {
        DecisionTree {
            nodes: vec![
                TreeNode::Split {
                    feature: 0,
                    threshold: 1.0,
                    left: 1,
                    right: 2,
                },
                TreeNode::Leaf { class: 0 },
                TreeNode::Split {
                    feature: 1,
                    threshold: -2.0,
                    left: 3,
                    right: 4,
                },
                TreeNode::Leaf { class: 1 },
                TreeNode::Leaf { class: 2 },
            ],
            n_features: 2,
            n_classes: 3,
        }
    }

    #[test]
    fn predict_and_steps() {
        let t = sample_tree();
        assert_eq!(t.walk(&[0.0, 0.0]), (0, 1));
        assert_eq!(t.walk(&[5.0, -3.0]), (1, 2));
        assert_eq!(t.walk(&[5.0, 0.0]), (2, 2));
        // boundary: equal goes right
        assert_eq!(t.predict(&[1.0, 0.0]), 2);
    }

    #[test]
    fn structure_stats() {
        let t = sample_tree();
        assert_eq!(t.n_nodes(), 5);
        assert_eq!(t.n_leaves(), 3);
        assert_eq!(t.depth(), 2);
        assert_eq!(DecisionTree::leaf(0, 2, 2).depth(), 0);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut t = sample_tree();
        t.validate().unwrap();
        t.nodes[0] = TreeNode::Split {
            feature: 9,
            threshold: 0.0,
            left: 1,
            right: 2,
        };
        assert!(t.validate().is_err());
        let mut t = sample_tree();
        t.nodes[2] = TreeNode::Split {
            feature: 0,
            threshold: 0.0,
            left: 0, // cycle back to root
            right: 4,
        };
        assert!(t.validate().is_err());
        let mut t = sample_tree();
        t.nodes.push(TreeNode::Leaf { class: 0 }); // orphan
        assert!(t.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let t = sample_tree();
        let encoded = t.to_json().to_string_compact();
        let decoded = DecisionTree::from_json(&Json::parse(&encoded).unwrap()).unwrap();
        assert_eq!(t, decoded);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(DecisionTree::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad = r#"{"n_features":1,"n_classes":1,"nodes":[{"f":0,"t":0,"l":5,"r":6}]}"#;
        assert!(DecisionTree::from_json(&Json::parse(bad).unwrap()).is_err());
    }
}
