//! CART decision-tree learner (Gini impurity, random feature subspace).
//!
//! This is the Weka-`RandomTree` substitute (DESIGN.md §Substitutions):
//! unpruned trees, `K` randomly chosen candidate features per node
//! (default `⌈√F⌉`), split thresholds at midpoints between distinct sorted
//! values, leaves on purity / depth / minimum-size stopping conditions.

use super::{DecisionTree, TreeNode};
use crate::data::Dataset;
use crate::util::rng::Rng;

/// Hyper-parameters for a single tree.
#[derive(Debug, Clone)]
pub struct TreeParams {
    /// Maximum depth; `0` means unlimited (Weka RandomTree default).
    pub max_depth: usize,
    /// Minimum rows per leaf.
    pub min_samples_leaf: usize,
    /// Minimum rows required to attempt a split.
    pub min_samples_split: usize,
    /// Candidate features per node; `0` means `⌈√F⌉`.
    pub k_features: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 0,
            min_samples_leaf: 1,
            min_samples_split: 2,
            k_features: 0,
        }
    }
}

/// Learner state for one tree induction.
pub struct TreeLearner<'a> {
    data: &'a Dataset,
    params: TreeParams,
    rng: Rng,
    nodes: Vec<TreeNode>,
}

/// Weighted Gini impurity of a class histogram with `total` rows.
fn gini(hist: &[usize], total: usize) -> f64 {
    if total == 0 {
        return 0.0;
    }
    let t = total as f64;
    1.0 - hist
        .iter()
        .map(|&c| {
            let p = c as f64 / t;
            p * p
        })
        .sum::<f64>()
}

fn majority(hist: &[usize]) -> u32 {
    hist.iter()
        .enumerate()
        .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0))) // ties -> lowest index
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

struct Split {
    feature: u32,
    threshold: f32,
    gain: f64,
}

impl<'a> TreeLearner<'a> {
    /// New learner over `data` with a dedicated RNG stream.
    pub fn new(data: &'a Dataset, params: TreeParams, rng: Rng) -> Self {
        TreeLearner {
            data,
            params,
            rng,
            nodes: Vec::new(),
        }
    }

    /// Induce a tree from the given row indices (duplicates allowed —
    /// bootstrap samples pass their multiset directly).
    pub fn fit(mut self, rows: &[usize]) -> DecisionTree {
        debug_assert!(!rows.is_empty(), "cannot fit a tree on zero rows");
        let mut rows = rows.to_vec();
        self.grow(&mut rows, 0);
        DecisionTree {
            nodes: self.nodes,
            n_features: self.data.n_features(),
            n_classes: self.data.n_classes(),
        }
    }

    fn histogram(&self, rows: &[usize]) -> Vec<usize> {
        let mut h = vec![0usize; self.data.n_classes()];
        for &r in rows {
            h[self.data.label(r) as usize] += 1;
        }
        h
    }

    /// Grow a subtree over `rows`; returns its arena index.
    fn grow(&mut self, rows: &mut [usize], depth: usize) -> u32 {
        let hist = self.histogram(rows);
        let total = rows.len();
        let pure = hist.iter().filter(|&&c| c > 0).count() <= 1;
        let depth_capped = self.params.max_depth > 0 && depth >= self.params.max_depth;
        if pure || depth_capped || total < self.params.min_samples_split {
            return self.push(TreeNode::Leaf {
                class: majority(&hist),
            });
        }
        let split = match self.best_split(rows, &hist) {
            Some(s) => s,
            None => {
                return self.push(TreeNode::Leaf {
                    class: majority(&hist),
                })
            }
        };
        // Partition rows in place: `< threshold` first.
        let mut mid = 0;
        for i in 0..rows.len() {
            if self.data.row(rows[i])[split.feature as usize] < split.threshold {
                rows.swap(i, mid);
                mid += 1;
            }
        }
        debug_assert!(mid > 0 && mid < rows.len(), "degenerate partition");
        let idx = self.push(TreeNode::Leaf { class: 0 }); // placeholder, patched below
        let (left_rows, right_rows) = rows.split_at_mut(mid);
        let left = self.grow(left_rows, depth + 1);
        let right = self.grow(right_rows, depth + 1);
        self.nodes[idx as usize] = TreeNode::Split {
            feature: split.feature,
            threshold: split.threshold,
            left,
            right,
        };
        idx
    }

    fn push(&mut self, node: TreeNode) -> u32 {
        self.nodes.push(node);
        (self.nodes.len() - 1) as u32
    }

    /// Best Gini split over a random subset of features.
    fn best_split(&mut self, rows: &[usize], hist: &[usize]) -> Option<Split> {
        let nf = self.data.n_features();
        let k = if self.params.k_features == 0 {
            (nf as f64).sqrt().ceil() as usize
        } else {
            self.params.k_features.min(nf)
        };
        let candidates = self.rng.sample_indices(nf, k);
        let parent_gini = gini(hist, rows.len());
        let mut best: Option<Split> = None;
        for f in candidates {
            if let Some(s) = self.best_split_on(rows, f, hist, parent_gini) {
                if best.as_ref().map(|b| s.gain > b.gain).unwrap_or(true) {
                    best = Some(s);
                }
            }
        }
        best.filter(|b| b.gain > 1e-12)
    }

    /// Best threshold on one feature via a sorted sweep with incremental
    /// class histograms (O(n log n) per feature).
    fn best_split_on(
        &self,
        rows: &[usize],
        feature: usize,
        hist: &[usize],
        parent_gini: f64,
    ) -> Option<Split> {
        let n = rows.len();
        let min_leaf = self.params.min_samples_leaf;
        let mut vals: Vec<(f32, u32)> = rows
            .iter()
            .map(|&r| (self.data.row(r)[feature], self.data.label(r)))
            .collect();
        vals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        if vals[0].0 == vals[n - 1].0 {
            return None; // constant feature
        }
        let mut left = vec![0usize; hist.len()];
        let mut best_gain = 0.0;
        let mut best_thr = None;
        let mut i = 0;
        while i < n {
            // advance over a run of equal values
            let v = vals[i].0;
            while i < n && vals[i].0 == v {
                left[vals[i].1 as usize] += 1;
                i += 1;
            }
            if i >= n {
                break;
            }
            let n_left = left.iter().sum::<usize>();
            let n_right = n - n_left;
            if n_left < min_leaf || n_right < min_leaf {
                continue;
            }
            let right: Vec<usize> = hist.iter().zip(&left).map(|(&h, &l)| h - l).collect();
            let g = (n_left as f64 * gini(&left, n_left)
                + n_right as f64 * gini(&right, n_right))
                / n as f64;
            let gain = parent_gini - g;
            if gain > best_gain {
                best_gain = gain;
                // midpoint between this run's value and the next distinct one
                best_thr = Some((v + vals[i].0) / 2.0);
            }
        }
        best_thr.map(|threshold| Split {
            feature: feature as u32,
            threshold,
            gain: best_gain,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{datasets, synth};

    fn fit_full(data: &Dataset, params: TreeParams, seed: u64) -> DecisionTree {
        let rows: Vec<usize> = (0..data.n_rows()).collect();
        TreeLearner::new(data, params, Rng::new(seed)).fit(&rows)
    }

    fn accuracy(tree: &DecisionTree, data: &Dataset) -> f64 {
        let correct = data
            .iter()
            .filter(|(x, y)| tree.predict(x) == *y)
            .count();
        correct as f64 / data.n_rows() as f64
    }

    #[test]
    fn gini_basics() {
        assert_eq!(gini(&[10, 0], 10), 0.0);
        assert!((gini(&[5, 5], 10) - 0.5).abs() < 1e-12);
        assert!((gini(&[1, 1, 1], 3) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn majority_tie_breaks_low() {
        assert_eq!(majority(&[3, 3, 1]), 0);
        assert_eq!(majority(&[1, 3, 3]), 1);
        assert_eq!(majority(&[0, 0, 4]), 2);
    }

    #[test]
    fn fits_iris_with_high_training_accuracy() {
        let ds = datasets::iris();
        let tree = fit_full(
            &ds,
            TreeParams {
                k_features: 4, // use all features -> plain CART
                ..Default::default()
            },
            0,
        );
        tree.validate().unwrap();
        assert!(accuracy(&tree, &ds) > 0.98, "acc {}", accuracy(&tree, &ds));
    }

    #[test]
    fn learns_exact_rules_on_lenses() {
        // Lenses is rule-defined; full unpruned CART must reach 100%.
        let ds = datasets::lenses();
        let tree = fit_full(
            &ds,
            TreeParams {
                k_features: 4,
                ..Default::default()
            },
            1,
        );
        assert_eq!(accuracy(&tree, &ds), 1.0);
    }

    #[test]
    fn depth_cap_respected() {
        let ds = datasets::iris();
        for cap in [1, 2, 3] {
            let tree = fit_full(
                &ds,
                TreeParams {
                    max_depth: cap,
                    k_features: 4,
                    ..Default::default()
                },
                0,
            );
            assert!(tree.depth() <= cap, "depth {} > cap {cap}", tree.depth());
        }
    }

    #[test]
    fn min_leaf_respected() {
        let ds = synth::blobs(&synth::BlobSpec {
            rows: 120,
            seed: 5,
            ..Default::default()
        })
        .unwrap();
        let tree = fit_full(
            &ds,
            TreeParams {
                min_samples_leaf: 10,
                k_features: 4,
                ..Default::default()
            },
            0,
        );
        // every leaf must hold >= 10 training rows; verify by routing all rows
        let mut counts = std::collections::HashMap::new();
        for (x, _) in ds.iter() {
            let mut i = 0u32;
            loop {
                match tree.nodes[i as usize] {
                    TreeNode::Leaf { .. } => break,
                    TreeNode::Split {
                        feature,
                        threshold,
                        left,
                        right,
                    } => {
                        i = if x[feature as usize] < threshold {
                            left
                        } else {
                            right
                        }
                    }
                }
            }
            *counts.entry(i).or_insert(0usize) += 1;
        }
        assert!(counts.values().all(|&c| c >= 10), "{counts:?}");
    }

    #[test]
    fn pure_input_gives_single_leaf() {
        let ds = datasets::iris();
        let rows: Vec<usize> = (0..50).collect(); // all setosa
        let tree = TreeLearner::new(&ds, TreeParams::default(), Rng::new(0)).fit(&rows);
        assert_eq!(tree.n_nodes(), 1);
        assert_eq!(tree.predict(ds.row(0)), 0);
    }

    #[test]
    fn random_subspace_varies_with_seed() {
        let ds = datasets::iris();
        let a = fit_full(&ds, TreeParams::default(), 1);
        let b = fit_full(&ds, TreeParams::default(), 2);
        assert_ne!(a, b, "different seeds should explore different subspaces");
        let a2 = fit_full(&ds, TreeParams::default(), 1);
        assert_eq!(a, a2, "same seed must reproduce the same tree");
    }

    #[test]
    fn bootstrap_multiset_supported() {
        let ds = datasets::iris();
        let rows = vec![0usize; 30]; // 30 copies of one row
        let tree = TreeLearner::new(&ds, TreeParams::default(), Rng::new(0)).fit(&rows);
        assert_eq!(tree.n_nodes(), 1); // pure by construction
    }
}
