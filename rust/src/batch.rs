//! Flat row-major batch matrices: the zero-copy input type of every
//! batch evaluation path.
//!
//! The batch pipeline used to pass `&[Vec<f32>]` around — one heap
//! allocation per row, one pointer chase per row access, and a full
//! re-materialisation at every layer boundary (HTTP parse → router →
//! backend). [`RowMatrix`] replaces that with a borrowed view over one
//! contiguous row-major buffer (`&[f32]` plus an `n_features` stride):
//! building a batch is appending floats to one `Vec`, passing it anywhere
//! is copying two words, and slicing a shard for a worker thread is
//! pointer arithmetic.
//!
//! [`RowMatrixBuf`] is the owned builder: the HTTP/JSON layer pushes
//! parsed cells straight into it (no intermediate per-row `Vec<f32>`),
//! the router's dynamic batcher packs coalesced single requests into one,
//! and [`Dataset::matrix`](crate::data::Dataset::matrix) exposes a whole
//! dataset as a `RowMatrix` for free (datasets already store cells
//! row-major).

use crate::error::{Error, Result};

/// A borrowed, row-major batch of feature rows: `data.len() ==
/// n_rows * n_features`, row `i` at `data[i * n_features ..][.. n_features]`.
///
/// `Copy` (two words), so it is passed by value everywhere — including
/// across the [`Classifier`](crate::classifier::Classifier) trait.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowMatrix<'a> {
    data: &'a [f32],
    n_features: usize,
    n_rows: usize,
}

impl<'a> RowMatrix<'a> {
    /// View `data` as rows of `n_features` cells. Errors when the buffer
    /// is not a whole number of rows (or `n_features == 0` with data).
    pub fn new(data: &'a [f32], n_features: usize) -> Result<RowMatrix<'a>> {
        if n_features == 0 {
            if !data.is_empty() {
                return Err(Error::invalid("RowMatrix with 0 features cannot hold data"));
            }
            return Ok(RowMatrix {
                data,
                n_features: 0,
                n_rows: 0,
            });
        }
        if data.len() % n_features != 0 {
            return Err(Error::invalid(format!(
                "buffer of {} cells is not a multiple of {n_features} features",
                data.len()
            )));
        }
        Ok(RowMatrix {
            data,
            n_features,
            n_rows: data.len() / n_features,
        })
    }

    /// The empty batch.
    pub fn empty() -> RowMatrix<'static> {
        RowMatrix {
            data: &[],
            n_features: 0,
            n_rows: 0,
        }
    }

    /// Number of rows.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Row stride (feature arity).
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// True when the batch holds no rows.
    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    /// The underlying contiguous cell buffer (row-major).
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// Feature row `i`.
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.data[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Iterate the rows in order.
    pub fn iter(&self) -> impl Iterator<Item = &'a [f32]> {
        // `max(1)` keeps `chunks_exact` legal for the 0-feature empty
        // matrix (whose data is empty, so the iterator yields nothing).
        self.data.chunks_exact(self.n_features.max(1))
    }

    /// Contiguous sub-batch of `len` rows starting at `start` — the
    /// zero-copy shard handed to each worker of a parallel sweep.
    pub fn slice(&self, start: usize, len: usize) -> RowMatrix<'a> {
        assert!(start + len <= self.n_rows, "shard out of bounds");
        RowMatrix {
            data: &self.data[start * self.n_features..(start + len) * self.n_features],
            n_features: self.n_features,
            n_rows: len,
        }
    }
}

/// The owned builder for [`RowMatrix`]: one growable flat buffer with a
/// fixed row stride. Producers append cells or whole rows; `as_matrix`
/// borrows the finished batch without copying.
#[derive(Debug, Clone, Default)]
pub struct RowMatrixBuf {
    data: Vec<f32>,
    n_features: usize,
    /// Cells belonging to rows already closed (streaming producers may
    /// hold a partial row beyond this watermark until `end_row`).
    complete: usize,
}

impl RowMatrixBuf {
    /// An empty buffer for rows of `n_features` cells.
    pub fn new(n_features: usize) -> RowMatrixBuf {
        RowMatrixBuf {
            data: Vec::new(),
            n_features,
            complete: 0,
        }
    }

    /// An empty buffer with capacity for `rows` rows.
    pub fn with_capacity(n_features: usize, rows: usize) -> RowMatrixBuf {
        RowMatrixBuf {
            data: Vec::with_capacity(n_features * rows),
            n_features,
            complete: 0,
        }
    }

    /// Copy a borrowed matrix into an owned buffer (one `memcpy`) — how
    /// batches cross thread boundaries (e.g. into the XLA engine thread).
    pub fn from_matrix(m: RowMatrix<'_>) -> RowMatrixBuf {
        RowMatrixBuf {
            data: m.data().to_vec(),
            n_features: m.n_features(),
            complete: m.data().len(),
        }
    }

    /// Row stride.
    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Completed rows.
    pub fn n_rows(&self) -> usize {
        if self.n_features == 0 {
            0
        } else {
            self.complete / self.n_features
        }
    }

    /// True when no cells have been pushed.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Append one whole row.
    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        if self.n_features == 0 || row.len() != self.n_features {
            return Err(Error::invalid(format!(
                "row has {} features, batch stride is {}",
                row.len(),
                self.n_features
            )));
        }
        self.data.extend_from_slice(row);
        self.complete = self.data.len();
        Ok(())
    }

    /// Append one whole row given as packed little-endian `f32` bytes
    /// (the wire layout of the binary row frame — deserialisation goes
    /// straight from the network buffer into batch cells).
    pub fn push_row_le_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        if self.n_features == 0 || bytes.len() != self.n_features * 4 {
            return Err(Error::invalid(format!(
                "row frame has {} bytes, batch stride needs {}",
                bytes.len(),
                self.n_features * 4
            )));
        }
        self.data.extend(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("chunk of 4"))),
        );
        self.complete = self.data.len();
        Ok(())
    }

    /// Append one cell of the row being built (streaming producers, e.g.
    /// the HTTP JSON parser). Close the row with [`end_row`](Self::end_row).
    pub fn push_cell(&mut self, v: f32) {
        self.data.push(v);
    }

    /// Close the row being built; errors when its cell count does not
    /// match the stride (the buffer is left unusable mid-row on error —
    /// callers bail out of the whole batch).
    pub fn end_row(&mut self) -> Result<()> {
        if self.n_features == 0 || self.data.len() != self.complete + self.n_features {
            return Err(Error::invalid(format!(
                "rows must all have exactly {} features",
                self.n_features
            )));
        }
        self.complete = self.data.len();
        Ok(())
    }

    /// Drop all rows, keeping the allocation (builder reuse).
    pub fn clear(&mut self) {
        self.data.clear();
        self.complete = 0;
    }

    /// Borrow the finished batch as a [`RowMatrix`] (complete rows only;
    /// a partial row pending `end_row` is not exposed).
    pub fn as_matrix(&self) -> RowMatrix<'_> {
        if self.n_features == 0 {
            return RowMatrix::empty();
        }
        RowMatrix {
            data: &self.data[..self.complete],
            n_features: self.n_features,
            n_rows: self.complete / self.n_features,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_views_rows_without_copying() {
        let cells = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let m = RowMatrix::new(&cells, 3).unwrap();
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.n_features(), 3);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        let rows: Vec<&[f32]> = m.iter().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], &[4.0, 5.0, 6.0]);
        assert!(std::ptr::eq(m.data().as_ptr(), cells.as_ptr()));
    }

    #[test]
    fn ragged_buffers_rejected() {
        let cells = [1.0f32, 2.0, 3.0];
        assert!(RowMatrix::new(&cells, 2).is_err());
        assert!(RowMatrix::new(&cells, 0).is_err());
        assert!(RowMatrix::new(&[], 0).is_ok());
        let e = RowMatrix::empty();
        assert!(e.is_empty());
        assert_eq!(e.iter().count(), 0);
    }

    #[test]
    fn slicing_shards_share_the_buffer() {
        let cells: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let m = RowMatrix::new(&cells, 2).unwrap();
        let shard = m.slice(2, 3);
        assert_eq!(shard.n_rows(), 3);
        assert_eq!(shard.row(0), &[4.0, 5.0]);
        assert_eq!(shard.row(2), &[8.0, 9.0]);
        assert!(std::ptr::eq(shard.data().as_ptr(), &cells[4]));
        assert_eq!(m.slice(6, 0).n_rows(), 0);
    }

    #[test]
    #[should_panic(expected = "shard out of bounds")]
    fn slicing_past_the_end_panics() {
        let cells = [0.0f32; 4];
        RowMatrix::new(&cells, 2).unwrap().slice(1, 2);
    }

    #[test]
    fn buf_builds_by_rows_and_cells() {
        let mut buf = RowMatrixBuf::with_capacity(2, 3);
        buf.push_row(&[1.0, 2.0]).unwrap();
        buf.push_cell(3.0);
        buf.push_cell(4.0);
        buf.end_row().unwrap();
        assert_eq!(buf.n_rows(), 2);
        let m = buf.as_matrix();
        assert_eq!(m.row(1), &[3.0, 4.0]);
        // stride violations are errors
        assert!(buf.push_row(&[9.0]).is_err());
        buf.push_cell(9.0);
        assert!(buf.end_row().is_err());
        buf.clear();
        assert!(buf.is_empty());
        assert_eq!(buf.as_matrix().n_rows(), 0);
        // a "row" holding two rows' worth of cells is one bad row, not two
        buf.push_cell(1.0);
        buf.push_cell(2.0);
        buf.push_cell(3.0);
        buf.push_cell(4.0);
        assert!(buf.end_row().is_err(), "double-width row must not pass");
        // partial rows are never exposed through as_matrix
        buf.clear();
        buf.push_cell(7.0);
        assert_eq!(buf.as_matrix().n_rows(), 0);
    }

    #[test]
    fn buf_accepts_little_endian_row_bytes() {
        let mut buf = RowMatrixBuf::with_capacity(2, 2);
        let mut wire = Vec::new();
        for v in [1.5f32, -2.0] {
            wire.extend_from_slice(&v.to_le_bytes());
        }
        buf.push_row_le_bytes(&wire).unwrap();
        assert_eq!(buf.n_rows(), 1);
        assert_eq!(buf.as_matrix().row(0), &[1.5, -2.0]);
        // a short frame is a stride violation, and must not consume cells
        assert!(buf.push_row_le_bytes(&wire[..4]).is_err());
        assert_eq!(buf.n_rows(), 1);
        // NaN survives the wire bit-for-bit (policy: accepted, not mangled)
        let nan_wire: Vec<u8> = [f32::NAN, 0.0]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        buf.push_row_le_bytes(&nan_wire).unwrap();
        assert!(buf.as_matrix().row(1)[0].is_nan());
    }

    #[test]
    fn from_matrix_copies_the_batch() {
        let cells = [1.0f32, 2.0, 3.0, 4.0];
        let m = RowMatrix::new(&cells, 2).unwrap();
        let owned = RowMatrixBuf::from_matrix(m);
        assert_eq!(owned.n_rows(), 2);
        assert_eq!(owned.as_matrix().row(1), &[3.0, 4.0]);
        // the degenerate empty batch round-trips to an empty matrix
        let empty = RowMatrixBuf::from_matrix(RowMatrix::empty());
        assert!(empty.as_matrix().is_empty());
    }
}
