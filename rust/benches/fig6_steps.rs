//! Fig. 6 reproduction: average classification steps vs forest size (Iris).
//!
//! Series: Random Forest, class-word DD, class-vector DD, most-frequent-
//! class DD, each with and without unsatisfiable-path elimination (`*`).
//! Non-`*` series are cut off when they exceed the node budget — the
//! paper's own curves stop there too.
//!
//! Env: FOREST_ADD_BENCH_MAX_TREES (default 10000), FOREST_ADD_BENCH_BUDGET.

use forest_add::bench_support::{paper_sweep, report, BenchEnv};
use forest_add::data::datasets;
use forest_add::util::table::fmt_thousands;

fn main() {
    let env = BenchEnv::load();
    let data = datasets::load("iris").expect("built-in dataset");
    let sweep = paper_sweep(&data, &env, 42);
    let table = sweep.to_table(|p| fmt_thousands(p.steps, 2));
    let notes = sweep.cutoff_notes();
    report(
        "fig6_steps",
        &format!(
            "Fig. 6 — mean classification steps vs forest size (iris, up to {} trees)",
            env.max_trees
        ),
        &table,
        &notes,
    );
}
