//! Serving benchmark: latency/throughput of the native backends (and XLA
//! when artifacts exist) through the router (systems extension beyond the
//! paper's step-count metric).
//!
//! Measures: single-request latency per backend (router-level, no HTTP
//! overhead), batched throughput vs batch size, and concurrent
//! multi-client throughput. All dispatch goes through `Classifier` trait
//! objects resolved from the `ModelRegistry` — the same path production
//! traffic takes. Env: FOREST_ADD_BENCH_SECONDS.

use forest_add::bench_support::{measure_ns, report, BenchEnv};
use forest_add::engine::Engine;
use forest_add::net::proto;
use forest_add::serve::batcher::BatcherConfig;
use forest_add::serve::breaker::BreakerBoard;
use forest_add::serve::config::{IoMode, ServeConfig};
use forest_add::serve::http::HttpClient;
use forest_add::serve::metrics::ServerMetrics;
use forest_add::serve::router::Router;
use forest_add::serve::{server, BackendKind, ClassifyRequest};
use forest_add::util::json::{self, Json};
use forest_add::util::table::Table;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let env = BenchEnv::load();
    let window = Duration::from_secs_f64(env.measure_secs);
    let data = forest_add::data::datasets::load("iris").unwrap();
    // `small` artifact geometry: 32 trees, depth 6. The engine loads the
    // XLA backend when artifacts exist and falls back to native otherwise.
    let engine = Engine::builder()
        .dataset(data.clone())
        .trees(32)
        .max_depth(6)
        .seed(7)
        .xla_artifacts("artifacts", "small")
        .build()
        .unwrap();
    let has_xla = engine
        .registry()
        .get(None)
        .map(|v| v.has(BackendKind::Xla))
        .unwrap_or(false);
    if !has_xla {
        eprintln!("[serving] xla unavailable; native backends only");
    }
    let router = Arc::new(Router::new(
        engine.registry().clone(),
        Arc::new(ServerMetrics::default()),
        BackendKind::Dd,
        BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(200),
            queue_cap: 4096,
        },
        Duration::from_secs(5),
        BreakerBoard::new(3, Duration::from_secs(1)),
    ));

    // --- single-request latency per backend -------------------------------
    let mut t = Table::new(&["backend", "mean latency", "throughput (req/s)"]);
    let mut backends = vec![BackendKind::Forest, BackendKind::Dd, BackendKind::Frozen];
    if has_xla {
        backends.push(BackendKind::Xla);
    }
    for &backend in &backends {
        let mut i = 0usize;
        let ns = measure_ns(window, || {
            let row = data.row(i % data.n_rows()).to_vec();
            i += 1;
            let resp = router
                .classify(&ClassifyRequest::new(row).on_backend(backend))
                .unwrap();
            std::hint::black_box(resp.class);
        });
        t.row(vec![
            backend.name().to_string(),
            format!("{:.1} us", ns / 1000.0),
            format!("{:.0}", 1e9 / ns),
        ]);
    }
    report(
        "serving_latency",
        "Serving — single-request latency per backend (router-level)",
        &t,
        &[],
    );

    // --- concurrent throughput (8 client threads, dd backend) --------------
    let mut t = Table::new(&["backend", "clients", "throughput (req/s)"]);
    for &backend in &backends {
        for clients in [1usize, 4, 8] {
            let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
            let count = Arc::new(std::sync::atomic::AtomicU64::new(0));
            std::thread::scope(|scope| {
                for c in 0..clients {
                    let router = router.clone();
                    let data = &data;
                    let stop = stop.clone();
                    let count = count.clone();
                    scope.spawn(move || {
                        let mut i = c;
                        while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                            let row = data.row(i % data.n_rows()).to_vec();
                            i += clients;
                            if router
                                .classify(&ClassifyRequest::new(row).on_backend(backend))
                                .is_ok()
                            {
                                count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            }
                        }
                    });
                }
                std::thread::sleep(window);
                stop.store(true, std::sync::atomic::Ordering::Relaxed);
            });
            let total = count.load(std::sync::atomic::Ordering::Relaxed);
            t.row(vec![
                backend.name().to_string(),
                clients.to_string(),
                format!("{:.0}", total as f64 / window.as_secs_f64()),
            ]);
        }
    }
    report(
        "serving_throughput",
        "Serving — concurrent throughput per backend",
        &t,
        &[],
    );

    // --- batched endpoint scaling ------------------------------------------
    // Small batches exercise the per-row fallbacks; the 1024/4096 points
    // cross both the frozen sweep's batch-vs-walk threshold and the
    // multi-core sharding crossover.
    let mut t = Table::new(&["backend", "batch", "rows/s"]);
    for &backend in &backends {
        for batch in [1usize, 16, 256, 1024, 4096] {
            let buf = forest_add::bench_support::tile_rows(&data, batch, 13);
            let rows = buf.as_matrix();
            let ns = measure_ns(window, || {
                let out = router
                    .classify_batch(rows, Some(backend), None, false, false)
                    .unwrap();
                std::hint::black_box(out.classes.len());
            });
            t.row(vec![
                backend.name().to_string(),
                batch.to_string(),
                format!("{:.0}", batch as f64 * 1e9 / ns),
            ]);
        }
    }
    report(
        "serving_batch",
        "Serving — batched classification scaling",
        &t,
        &[],
    );

    // --- HTTP round trip: sync vs evented front-end -------------------------
    // Full-stack latency for one keep-alive client (socket, incremental
    // parser, router, serialiser); the binary frame measures the
    // JSON-free row path end to end.
    let mut t = Table::new(&["front-end", "request", "mean latency", "req/s"]);
    let mut modes = vec![IoMode::Sync];
    if forest_add::net::poll::supported() {
        modes.push(IoMode::Evented);
    }
    for mode in modes {
        let handle = server::start(&ServeConfig {
            addr: "127.0.0.1:0".into(),
            dataset: "iris".into(),
            trees: 32,
            max_depth: 6,
            seed: 7,
            enable_xla: false,
            io_mode: mode,
            ..Default::default()
        })
        .unwrap();
        let addr = handle.addr.to_string();
        let mut client = HttpClient::connect(&addr).unwrap();
        let bodies: Vec<Vec<u8>> = (0..data.n_rows())
            .map(|i| {
                let row = Json::Arr(data.row(i).iter().map(|&v| json::num(v as f64)).collect());
                json::obj(vec![("features", row)])
                    .to_string_compact()
                    .into_bytes()
            })
            .collect();
        let mut i = 0usize;
        let ns = measure_ns(window, || {
            let body = &bodies[i % bodies.len()];
            i += 1;
            let (st, _, resp) = client
                .request_raw("POST", "/classify", "application/json", body)
                .unwrap();
            assert_eq!(st, 200);
            std::hint::black_box(resp.len());
        });
        t.row(vec![
            mode.name().to_string(),
            "json /classify".to_string(),
            format!("{:.1} us", ns / 1000.0),
            format!("{:.0}", 1e9 / ns),
        ]);
        let buf = forest_add::bench_support::tile_rows(&data, 64, 13);
        let frame = proto::encode_rows(buf.as_matrix()).unwrap();
        let ns = measure_ns(window, || {
            let (st, _, resp) = client
                .request_raw("POST", "/classify_batch", proto::BINARY_ROWS, &frame)
                .unwrap();
            assert_eq!(st, 200);
            std::hint::black_box(resp.len());
        });
        t.row(vec![
            mode.name().to_string(),
            "binary /classify_batch x64".to_string(),
            format!("{:.1} us", ns / 1000.0),
            format!("{:.0}", 1e9 / ns),
        ]);
        // hang up before stopping: a sync worker parked in a keep-alive
        // read would otherwise pin the join until the read timeout
        drop(client);
        handle.stop();
    }
    report(
        "serving_http",
        "Serving — HTTP round trip, sync vs evented front-end",
        &t,
        &[],
    );
}
