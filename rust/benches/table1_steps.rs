//! Table 1 reproduction: classification-step improvements at 10,000 trees
//! across the six UCI datasets (`Random Forest` vs `Final DD` =
//! most-frequent-class DD*).
//!
//! Env: FOREST_ADD_BENCH_TABLE_TREES (default 10000).

use forest_add::bench_support::{report, table_row_budgeted, BenchEnv};
use forest_add::data::datasets;
use forest_add::util::table::{fmt_reduction, fmt_thousands, Table};

fn main() {
    let env = BenchEnv::load();
    let mut table = Table::new(&["Dataset", "Random Forest", "Final DD", "reduction"]);
    let mut notes = Vec::new();
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        eprintln!("[table1] {name}: {} trees …", env.table_trees);
        let start = std::time::Instant::now();
        let (forest, dd, reached) = table_row_budgeted(
            &data,
            env.table_trees,
            42,
            std::time::Duration::from_secs(env.dataset_secs),
        );
        let forest = forest.prefix(reached);
        let rf = forest.mean_steps(&data);
        let dds = dd.mean_steps(&data);
        table.row(vec![
            format!("{} (n={reached})", pretty(name)),
            fmt_thousands(rf, 2),
            fmt_thousands(dds, 2),
            fmt_reduction(rf, dds),
        ]);
        notes.push(format!(
            "{name}: {reached}/{} trees within budget, compile {:.1?}, {} DD nodes",
            env.table_trees,
            start.elapsed(),
            dd.size().total()
        ));
    }
    report(
        "table1_steps",
        &format!(
            "Table 1 — running time (steps) improvements at {} trees",
            env.table_trees
        ),
        &table,
        &notes,
    );
}

fn pretty(name: &str) -> String {
    match name {
        "balance-scale" => "Balance Scale".into(),
        "breast-cancer" => "Breast Cancer".into(),
        "tic-tac-toe" => "Tic-Tac-Toe".into(),
        other => {
            let mut c = other.chars();
            match c.next() {
                Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
                None => String::new(),
            }
        }
    }
}
