//! Fig. 7 reproduction: structure sizes vs forest size (Iris).
//!
//! Same sweep as Fig. 6, reporting node counts: the Random Forest grows
//! linearly, the plain DDs explode (cut off at the node budget), and the
//! `*` variants stay compact — with the final `DD*` far below the forest.
//!
//! Env: FOREST_ADD_BENCH_MAX_TREES (default 10000), FOREST_ADD_BENCH_BUDGET.

use forest_add::bench_support::{paper_sweep, report, BenchEnv};
use forest_add::data::datasets;
use forest_add::util::table::fmt_thousands;

fn main() {
    let env = BenchEnv::load();
    let data = datasets::load("iris").expect("built-in dataset");
    let sweep = paper_sweep(&data, &env, 42);
    let table = sweep.to_table(|p| fmt_thousands(p.size as f64, 0));
    let notes = sweep.cutoff_notes();
    report(
        "fig7_sizes",
        &format!(
            "Fig. 7 — structure sizes (nodes) vs forest size (iris, up to {} trees)",
            env.max_trees
        ),
        &table,
        &notes,
    );
}
