//! Micro-benchmarks of the hot-path primitives — the before/after
//! instrument for the EXPERIMENTS.md §Perf iteration log.
//!
//! Covers: DD evaluation walk (pointer-walk vs frozen, single-row and
//! batch), forest walk, ADD combine, unsat reduction, tree→ADD conversion,
//! snapshot load, and the packed-tensor row evaluation that mirrors the L1
//! kernel.

use forest_add::add::reduce::reduce_feasible;
use forest_add::add::{ClassVector, Manager};
use forest_add::bench_support::{measure_ns, report, BenchEnv};
use forest_add::compile::{CompileOptions, ForestCompiler};
use forest_add::data::datasets;
use forest_add::forest::ForestLearner;
use forest_add::predicate::{PredicateOrder, PredicatePool};
use forest_add::util::table::Table;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let env = BenchEnv::load();
    let window = Duration::from_secs_f64(env.measure_secs.min(1.0));
    let data = datasets::load("iris").unwrap();
    let forest = ForestLearner::default().trees(100).seed(42).fit(&data);
    let dd = ForestCompiler::new(CompileOptions::default())
        .compile(&forest)
        .unwrap();

    let mut t = Table::new(&["operation", "time/op", "ops/s"]);
    let mut add_row = |t: &mut Table, name: &str, ns: f64| {
        t.row(vec![
            name.to_string(),
            if ns > 1e6 {
                format!("{:.2} ms", ns / 1e6)
            } else if ns > 1e3 {
                format!("{:.2} us", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            },
            format!("{:.0}", 1e9 / ns),
        ]);
    };

    // DD walk (the request-path primitive): pointer-walk arena vs the
    // frozen struct-of-arrays layout, then the two batch paths.
    let frozen = dd.freeze();
    let mut i = 0usize;
    let ns = measure_ns(window, || {
        let x = data.row(i % data.n_rows());
        i += 1;
        std::hint::black_box(dd.classify(x));
    });
    add_row(&mut t, "DD* classify (1 row, pointer walk)", ns);

    let mut i = 0usize;
    let ns = measure_ns(window, || {
        let x = data.row(i % data.n_rows());
        i += 1;
        std::hint::black_box(frozen.classify(x));
    });
    add_row(&mut t, "FrozenDD classify (1 row)", ns);

    let rows = data.matrix();
    let n_rows = rows.n_rows() as f64;
    let ns = measure_ns(window, || {
        let out = forest_add::classifier::Classifier::classify_batch(&dd, rows).unwrap();
        std::hint::black_box(out.len());
    });
    add_row(
        &mut t,
        "DD* classify_batch row (150 rows, pointer walk)",
        ns / n_rows,
    );

    let ns = measure_ns(window, || {
        let out = frozen.classify_batch(rows);
        std::hint::black_box(out.len());
    });
    add_row(&mut t, "FrozenDD classify_batch row (150 rows)", ns / n_rows);

    // the allocation-free steady state: warm scratch + reused output,
    // tiled past the sweep crossover (the serving fleet's batch shape)
    let tiled = forest_add::bench_support::tile_rows(&data, 4096, 1);
    let big = tiled.as_matrix();
    let mut scratch = forest_add::frozen::BatchScratch::new();
    let mut out = Vec::new();
    let ns = measure_ns(window, || {
        frozen.classify_batch_into(big, &mut scratch, &mut out);
        std::hint::black_box(out.len());
    });
    add_row(&mut t, "FrozenDD sweep row (4096 rows, warm scratch, 1 thread)", ns / 4096.0);

    let ns = measure_ns(window, || {
        let out = frozen.classify_batch(big);
        std::hint::black_box(out.len());
    });
    add_row(&mut t, "FrozenDD sweep row (4096 rows, sharded)", ns / 4096.0);

    // the cache-tiled chain sweep under a minimal budget (the shape big
    // diagrams take; on this small diagram it measures tiling overhead)
    let ns = measure_ns(window, || {
        frozen.classify_batch_into_tiled(big, &mut scratch, &mut out, 1);
        std::hint::black_box(out.len());
    });
    add_row(&mut t, "FrozenDD tiled sweep row (4096 rows, min tiles)", ns / 4096.0);

    // kernel-pinned pair on the same sweep: the scalar walk vs the best
    // SIMD kernel this host detects (identical rows on hosts with none)
    use forest_add::runtime::simd;
    let ns = measure_ns(window, || {
        frozen.classify_batch_kernel_into(big, &mut scratch, &mut out, 0, simd::Kernel::Scalar);
        std::hint::black_box(out.len());
    });
    add_row(&mut t, "FrozenDD sweep row (4096 rows, scalar kernel)", ns / 4096.0);

    let kernel = simd::kernel();
    let ns = measure_ns(window, || {
        frozen.classify_batch_kernel_into(big, &mut scratch, &mut out, 0, kernel);
        std::hint::black_box(out.len());
    });
    add_row(
        &mut t,
        &format!("FrozenDD sweep row (4096 rows, {} kernel)", kernel.name()),
        ns / 4096.0,
    );

    // the quantised + column-packed freeze on the same workload
    let opt = dd
        .freeze_with(forest_add::frozen::FreezeOpts {
            quantize_f16: true,
            pack_features: true,
        })
        .unwrap();
    let ns = measure_ns(window, || {
        opt.classify_batch_into(big, &mut scratch, &mut out);
        std::hint::black_box(out.len());
    });
    add_row(&mut t, "FrozenDD sweep row (4096 rows, f16 + packed)", ns / 4096.0);

    // snapshot load (the replica-startup primitive): in-memory parse vs
    // the mmap boot path replicas take
    let snapshot_bytes = frozen.to_bytes();
    let ns = measure_ns(window, || {
        let dd = forest_add::frozen::FrozenDD::from_bytes(&snapshot_bytes).unwrap();
        std::hint::black_box(dd.size().total());
    });
    add_row(&mut t, "FrozenDD snapshot load (fdd-v2, from_bytes)", ns);

    let snap_path = std::env::temp_dir().join(format!("microbench-{}.fdd", std::process::id()));
    let snap_path = snap_path.to_str().unwrap().to_string();
    frozen.save(&snap_path).unwrap();
    let ns = measure_ns(window, || {
        let dd = forest_add::frozen::FrozenDD::load(&snap_path).unwrap();
        std::hint::black_box(dd.size().total());
    });
    add_row(&mut t, "FrozenDD snapshot boot (fdd-v2, mmap)", ns);
    let _ = std::fs::remove_file(&snap_path);

    // bundle boot (the fleet-replica startup primitive): one mmap of a
    // 4-model fab-v1 artifact, every entry booted zero-copy
    use forest_add::frozen::bundle::{self, Bundle, BundleEntrySpec};
    let fab_path = std::env::temp_dir().join(format!("microbench-{}.fab", std::process::id()));
    let fab_path = fab_path.to_str().unwrap().to_string();
    let specs: Vec<BundleEntrySpec<'_>> = (0..4)
        .map(|i| BundleEntrySpec {
            name: format!("model-{i}"),
            version: 1,
            shard: format!("shard-{i}"),
            dd: &frozen,
        })
        .collect();
    bundle::save(&fab_path, &bundle::pack(&specs).unwrap()).unwrap();
    let ns = measure_ns(window, || {
        let b = Bundle::load(&fab_path).unwrap();
        let mut total = 0usize;
        for i in 0..b.len() {
            total += b.boot(i).unwrap().size().total();
        }
        std::hint::black_box(total);
    });
    add_row(&mut t, "fab bundle boot (fab-v1, 4 models, one mmap)", ns);
    let _ = std::fs::remove_file(&fab_path);

    // forest walk baseline
    let mut i = 0usize;
    let ns = measure_ns(window, || {
        let x = data.row(i % data.n_rows());
        i += 1;
        std::hint::black_box(forest.predict(x));
    });
    add_row(&mut t, "forest predict (100 trees, 1 row)", ns);

    // tree -> ADD conversion + combine (the compiler inner loop)
    let pool = Arc::new(PredicatePool::from_forest(
        &forest,
        PredicateOrder::FeatureThreshold,
    ));
    let n_classes = forest.n_classes();
    let ns = measure_ns(window, || {
        let mut mgr: Manager<ClassVector> = Manager::new(pool.clone());
        let mut acc = mgr.terminal(ClassVector::zero(n_classes));
        for tree in forest.trees.iter().take(10) {
            let t = mgr
                .from_tree(tree, &|c| ClassVector::unit(c as u16, n_classes))
                .unwrap();
            acc = mgr.combine(acc, t);
        }
        std::hint::black_box(mgr.size(acc).total());
    });
    add_row(&mut t, "aggregate 10 trees (fresh manager)", ns);

    // unsat reduction of a 10-tree aggregate
    let ns = measure_ns(window, || {
        let mut mgr: Manager<ClassVector> = Manager::new(pool.clone());
        let mut acc = mgr.terminal(ClassVector::zero(n_classes));
        for tree in forest.trees.iter().take(10) {
            let t = mgr
                .from_tree(tree, &|c| ClassVector::unit(c as u16, n_classes))
                .unwrap();
            acc = mgr.combine(acc, t);
        }
        let r = reduce_feasible(&mut mgr, acc);
        std::hint::black_box(r);
    });
    add_row(&mut t, "aggregate+reduce 10 trees", ns);

    // full compile throughput (DD*, 30-tree prefix — the per-tree cost
    // grows with diagram size; see EXPERIMENTS.md §Perf for the scaling)
    let prefix = forest.prefix(30);
    let ns = measure_ns(Duration::from_secs_f64(env.measure_secs), || {
        let dd = ForestCompiler::new(CompileOptions::default())
            .compile(&prefix)
            .unwrap();
        std::hint::black_box(dd.size().total());
    });
    add_row(&mut t, "full compile (30 trees, DD*)", ns);

    // packed-tensor row eval (rust mirror of the L1 kernel semantics)
    let shallow = ForestLearner::default()
        .trees(32)
        .max_depth(6)
        .seed(3)
        .fit(&data);
    let meta = forest_add::runtime::VariantMeta {
        name: "bench".into(),
        batch: 16,
        trees: 32,
        depth: 6,
        features: 8,
        classes: 4,
        n_nodes: 63,
        n_leaves: 64,
        hlo_file: String::new(),
    };
    let packed = forest_add::runtime::PackedForest::pack(&shallow, &meta).unwrap();
    let mut i = 0usize;
    let ns = measure_ns(window, || {
        let x = data.row(i % data.n_rows());
        i += 1;
        std::hint::black_box(packed.eval_row(x, 6, 3));
    });
    add_row(&mut t, "packed tensor eval (32 trees, 1 row)", ns);

    report("microbench", "Hot-path micro-benchmarks", &t, &[]);
}
