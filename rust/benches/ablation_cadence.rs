//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Reduction cadence** (`reduce_every`): the paper argues §5 that
//!    applying unsat elimination *during* aggregation is what makes the
//!    approach scale. Sweeping the cadence shows the trade-off between
//!    reduction overhead and intermediate-diagram growth.
//! 2. **Predicate order**: `(feature, threshold)`-sorted vs
//!    frequency-descending variable orders.
//!
//! Env: FOREST_ADD_BENCH_ABLATION_TREES (default 300).

use forest_add::compile::{Abstraction, CompileOptions, ForestCompiler};
use forest_add::data::datasets;
use forest_add::forest::ForestLearner;
use forest_add::predicate::PredicateOrder;
use forest_add::bench_support::report;
use forest_add::util::table::Table;

fn main() {
    let trees: usize = std::env::var("FOREST_ADD_BENCH_ABLATION_TREES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    let data = datasets::load("iris").unwrap();
    let forest = ForestLearner::default().trees(trees).seed(42).fit(&data);

    // --- cadence sweep ------------------------------------------------------
    let mut t = Table::new(&[
        "reduce_every",
        "compile time",
        "peak live nodes",
        "final nodes",
        "reductions",
    ]);
    let mut notes = Vec::new();
    for cadence in [1usize, 2, 5, 10, 25, 100] {
        let opts = CompileOptions {
            abstraction: Abstraction::Majority,
            unsat_elim: true,
            reduce_every: cadence,
            node_budget: 5_000_000,
            ..Default::default()
        };
        match ForestCompiler::new(opts).compile(&forest) {
            Ok(dd) => {
                t.row(vec![
                    cadence.to_string(),
                    format!("{:.2?}", dd.stats.elapsed),
                    dd.stats.peak_live.to_string(),
                    dd.size().total().to_string(),
                    dd.stats.reduces.to_string(),
                ]);
            }
            Err(e) => {
                t.row(vec![
                    cadence.to_string(),
                    "—".into(),
                    "exploded".into(),
                    "—".into(),
                    "—".into(),
                ]);
                notes.push(format!("cadence {cadence}: {e}"));
            }
        }
    }
    report(
        "ablation_cadence",
        &format!("Ablation — unsat-elimination cadence (iris, {trees} trees)"),
        &t,
        &notes,
    );

    // --- predicate order ------------------------------------------------------
    let mut t = Table::new(&["order", "compile time", "final nodes", "mean steps"]);
    for (name, order) in [
        ("feature-threshold", PredicateOrder::FeatureThreshold),
        ("frequency-desc", PredicateOrder::FrequencyDesc),
    ] {
        let opts = CompileOptions {
            order,
            node_budget: 5_000_000,
            ..Default::default()
        };
        match ForestCompiler::new(opts).compile(&forest) {
            Ok(dd) => {
                t.row(vec![
                    name.to_string(),
                    format!("{:.2?}", dd.stats.elapsed),
                    dd.size().total().to_string(),
                    format!("{:.2}", dd.mean_steps(&data)),
                ]);
            }
            Err(e) => {
                t.row(vec![name.to_string(), "—".into(), format!("{e}"), "—".into()]);
            }
        }
    }
    report(
        "ablation_order",
        &format!("Ablation — predicate order (iris, {trees} trees)"),
        &t,
        &[],
    );
}
