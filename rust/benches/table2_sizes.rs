//! Table 2 reproduction: decision-diagram sizes at 10,000 trees across the
//! six UCI datasets (`Random Forest` node count vs `Final DD` node count).
//!
//! Env: FOREST_ADD_BENCH_TABLE_TREES (default 10000).

use forest_add::bench_support::{report, table_row_budgeted, BenchEnv};
use forest_add::data::datasets;
use forest_add::util::table::{fmt_reduction, fmt_thousands, Table};

fn main() {
    let env = BenchEnv::load();
    let mut table = Table::new(&["Dataset", "Random Forest", "Final DD", "reduction"]);
    let mut notes = Vec::new();
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        eprintln!("[table2] {name}: {} trees …", env.table_trees);
        let (forest, dd, reached) = table_row_budgeted(
            &data,
            env.table_trees,
            42,
            std::time::Duration::from_secs(env.dataset_secs),
        );
        let forest = forest.prefix(reached);
        let rf = forest.n_nodes() as f64;
        let dds = dd.size().total() as f64;
        table.row(vec![
            format!("{name} (n={reached})"),
            fmt_thousands(rf, 0),
            fmt_thousands(dds, 0),
            fmt_reduction(rf, dds),
        ]);
        notes.push(format!(
            "{name}: {reached}/{} trees within budget, {} decision + {} terminal nodes",
            env.table_trees,
            dd.size().internal,
            dd.size().terminals
        ));
    }
    report(
        "table2_sizes",
        &format!(
            "Table 2 — decision diagram sizes at {} trees",
            env.table_trees
        ),
        &table,
        &notes,
    );
}
