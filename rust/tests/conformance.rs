//! Backend conformance: every evaluator registered behind the
//! `Classifier` trait must produce identical labels — the paper's
//! semantic-equivalence guarantee, enforced across the whole backend
//! matrix through the exact dispatch path production traffic uses
//! (trait objects resolved from the `ModelRegistry`).

use forest_add::classifier::{self, Classifier};
use forest_add::compile::{Abstraction, CompileOptions, CompiledDD, ForestCompiler};
use forest_add::data::synth::{blobs, BlobSpec};
use forest_add::data::{datasets, Dataset};
use forest_add::engine::ModelRegistry;
use forest_add::forest::ForestLearner;
use forest_add::frozen::FrozenDD;
use forest_add::serve::BackendKind;
use forest_add::util::json::Json;
use forest_add::util::prop::{check, Config, Gen};
use std::sync::Arc;

/// Build a registry holding the forest baseline plus one model per DD
/// abstraction (± unsatisfiable-path elimination) and the frozen
/// rendering of each diagram, all compiled from the same forest.
fn registry_for(
    data: &Dataset,
    trees: usize,
    seed: u64,
) -> Result<(ModelRegistry, Vec<String>), String> {
    let forest = ForestLearner::default()
        .trees(trees)
        .seed(seed)
        .fit(data);
    let registry = ModelRegistry::new();
    let schema = data.schema.clone();
    registry
        .register(
            "forest",
            schema.clone(),
            vec![(
                BackendKind::Forest,
                Arc::new(forest.clone()) as Arc<dyn Classifier>,
            )],
        )
        .map_err(|e| e.to_string())?;
    let mut names = vec!["forest".to_string()];
    for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
        for unsat in [false, true] {
            let dd = ForestCompiler::new(CompileOptions {
                abstraction,
                unsat_elim: unsat,
                ..Default::default()
            })
            .compile(&forest)
            .map_err(|e| format!("{abstraction:?} unsat={unsat}: {e}"))?;
            let name = format!("{abstraction:?}-{unsat}").to_lowercase();
            // … and the frozen rendering of the same diagram as its own
            // single-backend model, so the property covers it too.
            let frozen_name = format!("{name}-frozen");
            registry
                .register(
                    frozen_name.as_str(),
                    schema.clone(),
                    vec![(
                        BackendKind::Frozen,
                        Arc::new(dd.freeze()) as Arc<dyn Classifier>,
                    )],
                )
                .map_err(|e| e.to_string())?;
            registry
                .register(
                    name.as_str(),
                    schema.clone(),
                    vec![(BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>)],
                )
                .map_err(|e| e.to_string())?;
            names.push(name);
            names.push(frozen_name);
        }
    }
    Ok((registry, names))
}

/// Property: on random synthetic datasets, the forest walker and all six
/// DD variants agree row-for-row through the trait, and each backend's
/// batch path agrees with its own single-row path.
#[test]
fn prop_backends_agree_through_classifier_trait() {
    check(
        "backend conformance",
        Config {
            cases: 10,
            ..Config::default()
        },
        |g: &mut Gen| {
            let spec = BlobSpec {
                rows: g.usize(20, 60),
                features: g.usize(2, 4),
                classes: g.usize(2, 4),
                separation: g.f64(1.0, 4.0),
                noise: 1.0,
                seed: g.int(0, 1 << 30) as u64,
            };
            let data = blobs(&spec).map_err(|e| e.to_string())?;
            let trees = g.usize(3, 14);
            let (registry, names) = registry_for(&data, trees, spec.seed ^ 0xA5)?;
            let rows = data.matrix();

            // reference labels from the forest baseline, via the trait
            let (_, baseline) = registry
                .resolve(Some("forest"), None)
                .map_err(|e| e.to_string())?;
            let reference = baseline
                .classifier
                .classify_batch(rows)
                .map_err(|e| e.to_string())?;

            for name in &names {
                let (_, slot) = registry
                    .resolve(Some(name.as_str()), None)
                    .map_err(|e| e.to_string())?;
                let c = slot.classifier.as_ref();
                let batch = c.classify_batch(rows).map_err(|e| e.to_string())?;
                if batch != reference {
                    return Err(format!(
                        "model '{name}' diverges from the forest baseline ({} trees, seed {})",
                        trees, spec.seed
                    ));
                }
                for (i, row) in rows.iter().enumerate() {
                    let single = c.classify(row).map_err(|e| e.to_string())?;
                    if single != batch[i] {
                        return Err(format!(
                            "model '{name}' row {i}: batch={} single={single}",
                            batch[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The agreement helper reports exactly 1.0 across the registry on a
/// fixed dataset (cheap smoke version of the property above).
#[test]
fn agreement_helper_is_exactly_one_on_iris() {
    let data = datasets::iris();
    let (registry, names) = registry_for(&data, 12, 42).unwrap();
    let (_, baseline) = registry.resolve(Some("forest"), None).unwrap();
    for name in &names {
        let (_, slot) = registry.resolve(Some(name.as_str()), None).unwrap();
        let agree = classifier::agreement(
            baseline.classifier.as_ref(),
            slot.classifier.as_ref(),
            &data,
        )
        .unwrap();
        assert_eq!(agree, 1.0, "{name}");
    }
}

/// Persistence conformance: on **every** built-in dataset and **every**
/// abstraction, the JSON-persisted-then-reloaded diagram, the frozen
/// form, and the snapshot-roundtripped frozen form must all be
/// bit-identical to the live `CompiledDD` — class *and* §6 step count,
/// single-row *and* batch paths — and must agree with the source forest
/// on every row. Snapshot bytes must survive `write → load → re-write`
/// unchanged.
#[test]
fn persisted_and_frozen_diagrams_conform_on_every_dataset() {
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        let forest = ForestLearner::default().trees(8).seed(13).fit(&data);
        let rows = data.matrix();
        for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
            let tag = format!("{name}/{abstraction:?}");
            let dd = ForestCompiler::new(CompileOptions {
                abstraction,
                ..Default::default()
            })
            .compile(&forest)
            .unwrap();

            // JSON round-trip (replica path before fdd-v1 existed).
            let text = dd.to_persist_json().to_string_compact();
            let from_json = CompiledDD::load_from_json(&Json::parse(&text).unwrap()).unwrap();

            // Frozen + binary snapshot round-trip.
            let frozen = dd.freeze();
            assert_eq!(frozen.size(), dd.size(), "{tag}: freezing changed the size");
            let bytes = frozen.to_bytes();
            let from_snapshot = FrozenDD::from_bytes(&bytes).unwrap();
            assert_eq!(
                from_snapshot.to_bytes(),
                bytes,
                "{tag}: snapshot bytes must round-trip unchanged"
            );

            // Batch paths (trait default for the live DD, node-array pass
            // for the frozen forms).
            let dd_batch = Classifier::classify_batch(&dd, rows).unwrap();
            let frozen_batch = frozen.classify_batch(rows);
            let snapshot_batch = from_snapshot.classify_batch(rows);

            for (i, x) in rows.iter().enumerate() {
                let want = forest.predict(x);
                let live = dd.classify_with_steps(x);
                assert_eq!(live.0, want, "{tag} row {i}: diagram vs forest");
                assert_eq!(
                    from_json.classify_with_steps(x),
                    live,
                    "{tag} row {i}: json round-trip"
                );
                assert_eq!(
                    frozen.classify_with_steps(x),
                    live,
                    "{tag} row {i}: frozen"
                );
                assert_eq!(
                    from_snapshot.classify_with_steps(x),
                    live,
                    "{tag} row {i}: snapshot round-trip"
                );
                assert_eq!(dd_batch[i], live.0, "{tag} row {i}: dd batch");
                assert_eq!(frozen_batch[i], live.0, "{tag} row {i}: frozen batch");
                assert_eq!(snapshot_batch[i], live.0, "{tag} row {i}: snapshot batch");
            }
        }
    }
}

/// When XLA artifacts exist, the tensorised backend joins the same
/// conformance check through the same trait object path.
#[test]
fn xla_backend_conforms_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/index.json").exists() {
        eprintln!("skipping xla conformance: run `make artifacts` first");
        return;
    }
    let data = datasets::iris();
    // small variant geometry: 32 trees, depth 6
    let forest = ForestLearner::default()
        .trees(32)
        .max_depth(6)
        .seed(11)
        .fit(&data);
    let dd = ForestCompiler::new(CompileOptions::default())
        .compile(&forest)
        .unwrap();
    let xla = match forest_add::serve::xla_backend::XlaBackend::start("artifacts", "small", &forest)
    {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping xla conformance: backend unavailable: {e}");
            return;
        }
    };
    let registry = ModelRegistry::new();
    registry
        .register(
            "default",
            data.schema.clone(),
            vec![
                (
                    BackendKind::Forest,
                    Arc::new(forest) as Arc<dyn Classifier>,
                ),
                (BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>),
                (BackendKind::Xla, Arc::new(xla) as Arc<dyn Classifier>),
            ],
        )
        .unwrap();
    let version = registry.get(None).unwrap();
    let rows = data.matrix();
    let reference = version
        .slot(BackendKind::Forest)
        .unwrap()
        .classifier
        .classify_batch(rows)
        .unwrap();
    for kind in [BackendKind::Dd, BackendKind::Xla] {
        let got = version
            .slot(kind)
            .unwrap()
            .classifier
            .classify_batch(rows)
            .unwrap();
        assert_eq!(got, reference, "backend {}", kind.name());
    }
}

/// Sharded-parallel batch evaluation must be bit-identical to the
/// single-threaded per-row path for every backend × abstraction ×
/// dataset. Batches are tiled far past both the frozen sweep's
/// batch-vs-walk threshold and the multi-core sharding crossover, so the
/// parallel code genuinely runs (on multi-core hosts) and its contiguous
/// shard/disjoint-output scheme is pinned against the serial truth.
#[test]
fn sharded_batches_are_bit_identical_to_single_thread() {
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        let (registry, names) = registry_for(&data, 6, 29).unwrap();
        // Tile to 2048 rows (≥ every backend's parallel crossover).
        let tiled = forest_add::bench_support::tile_rows(&data, 2048, 13);
        let rows = tiled.as_matrix();
        for model in &names {
            let (_, slot) = registry.resolve(Some(model.as_str()), None).unwrap();
            let c = slot.classifier.as_ref();
            let batch = c.classify_batch(rows).unwrap();
            assert_eq!(batch.len(), rows.n_rows());
            // serial truth: one classify per row through the same trait
            for (i, row) in rows.iter().enumerate() {
                assert_eq!(
                    batch[i],
                    c.classify(row).unwrap(),
                    "{name}/{model} row {i}: sharded batch diverged"
                );
            }
        }
    }
}
