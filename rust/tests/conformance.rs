//! Backend conformance: every evaluator registered behind the
//! `Classifier` trait must produce identical labels — the paper's
//! semantic-equivalence guarantee, enforced across the whole backend
//! matrix through the exact dispatch path production traffic uses
//! (trait objects resolved from the `ModelRegistry`).

use forest_add::classifier::{self, Classifier};
use forest_add::compile::{Abstraction, CompileOptions, CompiledDD, ForestCompiler};
use forest_add::data::synth::{blobs, BlobSpec};
use forest_add::data::{datasets, Dataset};
use forest_add::engine::ModelRegistry;
use forest_add::forest::ForestLearner;
use forest_add::frozen::FrozenDD;
use forest_add::serve::BackendKind;
use forest_add::util::json::Json;
use forest_add::util::prop::{check, Config, Gen};
use std::sync::Arc;

/// Build a registry holding the forest baseline plus one model per DD
/// abstraction (± unsatisfiable-path elimination) and the frozen
/// rendering of each diagram, all compiled from the same forest.
fn registry_for(
    data: &Dataset,
    trees: usize,
    seed: u64,
) -> Result<(ModelRegistry, Vec<String>), String> {
    let forest = ForestLearner::default()
        .trees(trees)
        .seed(seed)
        .fit(data);
    let registry = ModelRegistry::new();
    let schema = data.schema.clone();
    registry
        .register(
            "forest",
            schema.clone(),
            vec![(
                BackendKind::Forest,
                Arc::new(forest.clone()) as Arc<dyn Classifier>,
            )],
        )
        .map_err(|e| e.to_string())?;
    let mut names = vec!["forest".to_string()];
    for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
        for unsat in [false, true] {
            let dd = ForestCompiler::new(CompileOptions {
                abstraction,
                unsat_elim: unsat,
                ..Default::default()
            })
            .compile(&forest)
            .map_err(|e| format!("{abstraction:?} unsat={unsat}: {e}"))?;
            let name = format!("{abstraction:?}-{unsat}").to_lowercase();
            // … and the frozen rendering of the same diagram as its own
            // single-backend model, so the property covers it too.
            let frozen_name = format!("{name}-frozen");
            registry
                .register(
                    frozen_name.as_str(),
                    schema.clone(),
                    vec![(
                        BackendKind::Frozen,
                        Arc::new(dd.freeze()) as Arc<dyn Classifier>,
                    )],
                )
                .map_err(|e| e.to_string())?;
            registry
                .register(
                    name.as_str(),
                    schema.clone(),
                    vec![(BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>)],
                )
                .map_err(|e| e.to_string())?;
            names.push(name);
            names.push(frozen_name);
        }
    }
    Ok((registry, names))
}

/// Property: on random synthetic datasets, the forest walker and all six
/// DD variants agree row-for-row through the trait, and each backend's
/// batch path agrees with its own single-row path.
#[test]
fn prop_backends_agree_through_classifier_trait() {
    check(
        "backend conformance",
        Config {
            cases: 10,
            ..Config::default()
        },
        |g: &mut Gen| {
            let spec = BlobSpec {
                rows: g.usize(20, 60),
                features: g.usize(2, 4),
                classes: g.usize(2, 4),
                separation: g.f64(1.0, 4.0),
                noise: 1.0,
                seed: g.int(0, 1 << 30) as u64,
            };
            let data = blobs(&spec).map_err(|e| e.to_string())?;
            let trees = g.usize(3, 14);
            let (registry, names) = registry_for(&data, trees, spec.seed ^ 0xA5)?;
            let rows = data.matrix();

            // reference labels from the forest baseline, via the trait
            let (_, baseline) = registry
                .resolve(Some("forest"), None)
                .map_err(|e| e.to_string())?;
            let reference = baseline
                .classifier
                .classify_batch(rows)
                .map_err(|e| e.to_string())?;

            for name in &names {
                let (_, slot) = registry
                    .resolve(Some(name.as_str()), None)
                    .map_err(|e| e.to_string())?;
                let c = slot.classifier.as_ref();
                let batch = c.classify_batch(rows).map_err(|e| e.to_string())?;
                if batch != reference {
                    return Err(format!(
                        "model '{name}' diverges from the forest baseline ({} trees, seed {})",
                        trees, spec.seed
                    ));
                }
                for (i, row) in rows.iter().enumerate() {
                    let single = c.classify(row).map_err(|e| e.to_string())?;
                    if single != batch[i] {
                        return Err(format!(
                            "model '{name}' row {i}: batch={} single={single}",
                            batch[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// The agreement helper reports exactly 1.0 across the registry on a
/// fixed dataset (cheap smoke version of the property above).
#[test]
fn agreement_helper_is_exactly_one_on_iris() {
    let data = datasets::iris();
    let (registry, names) = registry_for(&data, 12, 42).unwrap();
    let (_, baseline) = registry.resolve(Some("forest"), None).unwrap();
    for name in &names {
        let (_, slot) = registry.resolve(Some(name.as_str()), None).unwrap();
        let agree = classifier::agreement(
            baseline.classifier.as_ref(),
            slot.classifier.as_ref(),
            &data,
        )
        .unwrap();
        assert_eq!(agree, 1.0, "{name}");
    }
}

/// Persistence conformance: on **every** built-in dataset and **every**
/// abstraction, the JSON-persisted-then-reloaded diagram, the frozen
/// form, the snapshot-roundtripped frozen form, the **mmap-loaded**
/// snapshot, and the **v1-upgraded** legacy artifact must all be
/// bit-identical to the live `CompiledDD` — class *and* §6 step count,
/// single-row *and* batch paths (including the batch steps variant and
/// every tile budget tested) — and must agree with the source forest on
/// every row. Snapshot bytes must survive `write → load → re-write`
/// unchanged.
#[test]
fn persisted_and_frozen_diagrams_conform_on_every_dataset() {
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        let forest = ForestLearner::default().trees(8).seed(13).fit(&data);
        let rows = data.matrix();
        for abstraction in [Abstraction::Word, Abstraction::Vector, Abstraction::Majority] {
            let tag = format!("{name}/{abstraction:?}");
            let dd = ForestCompiler::new(CompileOptions {
                abstraction,
                ..Default::default()
            })
            .compile(&forest)
            .unwrap();

            // JSON round-trip (replica path before fdd-v1 existed).
            let text = dd.to_persist_json().to_string_compact();
            let from_json = CompiledDD::load_from_json(&Json::parse(&text).unwrap()).unwrap();

            // Frozen + binary snapshot round-trip.
            let frozen = dd.freeze();
            assert_eq!(frozen.size(), dd.size(), "{tag}: freezing changed the size");
            let bytes = frozen.to_bytes();
            let from_snapshot = FrozenDD::from_bytes(&bytes).unwrap();
            assert_eq!(
                from_snapshot.to_bytes(),
                bytes,
                "{tag}: snapshot bytes must round-trip unchanged"
            );

            // Legacy v1 artifact upgraded on load.
            let from_v1 = FrozenDD::from_bytes(&forest_add::frozen::snapshot::to_bytes_v1(
                &frozen,
            ))
            .unwrap();

            // The replica boot path: save to disk, mmap back.
            let path = std::env::temp_dir().join(format!(
                "conf-{}-{}-{:?}.fdd",
                std::process::id(),
                name,
                abstraction
            ));
            let path_s = path.to_str().unwrap().to_string();
            frozen.save(&path_s).unwrap();
            let mapped = FrozenDD::load(&path_s).unwrap();
            assert_eq!(
                mapped.mapped(),
                forest_add::runtime::mmap::enabled(),
                "{tag}: snapshot load must map where supported"
            );

            // Batch paths (trait default for the live DD, sweeps for the
            // frozen forms) + the steps-metered batch variant.
            let dd_batch = Classifier::classify_batch(&dd, rows).unwrap();
            let frozen_batch = frozen.classify_batch(rows);
            let snapshot_batch = from_snapshot.classify_batch(rows);
            let (mapped_batch, mapped_steps) = mapped.classify_batch_steps(rows);
            let (v1_batch, v1_steps) = from_v1.classify_batch_steps(rows);

            for (i, x) in rows.iter().enumerate() {
                let want = forest.predict(x);
                let live = dd.classify_with_steps(x);
                assert_eq!(live.0, want, "{tag} row {i}: diagram vs forest");
                assert_eq!(
                    from_json.classify_with_steps(x),
                    live,
                    "{tag} row {i}: json round-trip"
                );
                assert_eq!(
                    frozen.classify_with_steps(x),
                    live,
                    "{tag} row {i}: frozen"
                );
                assert_eq!(
                    from_snapshot.classify_with_steps(x),
                    live,
                    "{tag} row {i}: snapshot round-trip"
                );
                assert_eq!(
                    mapped.classify_with_steps(x),
                    live,
                    "{tag} row {i}: mmap-loaded snapshot"
                );
                assert_eq!(
                    from_v1.classify_with_steps(x),
                    live,
                    "{tag} row {i}: v1-upgraded snapshot"
                );
                assert_eq!(dd_batch[i], live.0, "{tag} row {i}: dd batch");
                assert_eq!(frozen_batch[i], live.0, "{tag} row {i}: frozen batch");
                assert_eq!(snapshot_batch[i], live.0, "{tag} row {i}: snapshot batch");
                assert_eq!(mapped_batch[i], live.0, "{tag} row {i}: mmap batch");
                assert_eq!(v1_batch[i], live.0, "{tag} row {i}: v1 batch");
                assert_eq!(
                    mapped_steps[i] as usize, live.1,
                    "{tag} row {i}: mmap batch steps"
                );
                assert_eq!(
                    v1_steps[i] as usize, live.1,
                    "{tag} row {i}: v1 batch steps"
                );
            }

            // Every tile budget yields the same classes and steps as the
            // single-row walk (1 forces minimum tiles, 0 = global auto).
            let mut scratch = forest_add::frozen::BatchScratch::new();
            let (mut out, mut steps) = (Vec::new(), Vec::new());
            for tile_budget in [1usize, 4096, 0] {
                mapped.classify_batch_steps_into_tiled(
                    rows,
                    &mut scratch,
                    &mut out,
                    &mut steps,
                    tile_budget,
                );
                assert_eq!(out, mapped_batch, "{tag}: tile budget {tile_budget}");
                assert_eq!(
                    steps, mapped_steps,
                    "{tag}: tile budget {tile_budget} steps"
                );
            }

            drop(mapped);
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// Every entry of a `fab-v1` bundle must be *bit-identical* to its
/// standalone `fdd-v2` snapshot — packing is packaging, never a
/// re-encoding — and the booted entry must classify (class + §6 steps,
/// single-row and batch) exactly like the standalone-loaded model.
#[test]
fn bundle_entries_conform_to_standalone_snapshots() {
    // Distinct datasets AND abstractions, so the bundle mixes schemas,
    // terminal layouts and section sizes in one file.
    let members: Vec<(String, Dataset, Abstraction)> = vec![
        ("iris".into(), datasets::load("iris").unwrap(), Abstraction::Majority),
        ("ttt".into(), datasets::load("tic-tac-toe").unwrap(), Abstraction::Vector),
        ("lenses".into(), datasets::load("lenses").unwrap(), Abstraction::Word),
    ];
    let mut frozen_models = Vec::new();
    let mut fdd_paths = Vec::new();
    for (name, data, abstraction) in &members {
        let forest = ForestLearner::default().trees(9).seed(29).fit(data);
        let frozen = ForestCompiler::new(CompileOptions {
            abstraction: *abstraction,
            ..Default::default()
        })
        .compile(&forest)
        .unwrap()
        .freeze();
        let path = std::env::temp_dir().join(format!(
            "conf-bundle-{}-{name}.fdd",
            std::process::id()
        ));
        let path_s = path.to_str().unwrap().to_string();
        frozen.save(&path_s).unwrap();
        frozen_models.push(frozen);
        fdd_paths.push(path_s);
    }
    let specs: Vec<forest_add::frozen::bundle::BundleEntrySpec<'_>> = members
        .iter()
        .zip(&frozen_models)
        .enumerate()
        .map(|(i, ((name, _, _), dd))| forest_add::frozen::bundle::BundleEntrySpec {
            name: name.clone(),
            version: 1,
            shard: format!("shard-{i}"),
            dd,
        })
        .collect();
    let fab_path = std::env::temp_dir().join(format!("conf-bundle-{}.fab", std::process::id()));
    let fab_path_s = fab_path.to_str().unwrap().to_string();
    forest_add::frozen::bundle::save(
        &fab_path_s,
        &forest_add::frozen::bundle::pack(&specs).unwrap(),
    )
    .unwrap();

    let fab_bytes = std::fs::read(&fab_path).unwrap();
    let bundle = forest_add::frozen::bundle::Bundle::load(&fab_path_s).unwrap();
    assert_eq!(bundle.len(), members.len());
    for (i, (name, data, _)) in members.iter().enumerate() {
        let tag = format!("bundle/{name}");
        let entry = &bundle.entries()[i];
        assert_eq!(&entry.name, name, "{tag}: manifest order");
        // bit-identity: the entry's bytes ARE the standalone artifact
        let standalone_bytes = std::fs::read(&fdd_paths[i]).unwrap();
        assert_eq!(
            &fab_bytes[entry.offset..entry.offset + entry.len],
            &standalone_bytes[..],
            "{tag}: bundle entry diverges from the standalone fdd-v2 snapshot"
        );
        // and the booted entry conforms to the standalone-loaded model
        let booted = bundle.boot(i).unwrap();
        let standalone = FrozenDD::load(&fdd_paths[i]).unwrap();
        let rows = data.matrix();
        let (b_batch, b_steps) = booted.classify_batch_steps(rows);
        let (s_batch, s_steps) = standalone.classify_batch_steps(rows);
        assert_eq!(b_batch, s_batch, "{tag}: batch classes");
        assert_eq!(b_steps, s_steps, "{tag}: batch steps");
        for (r, x) in rows.iter().enumerate() {
            let want = standalone.classify_with_steps(x);
            assert_eq!(booted.classify_with_steps(x), want, "{tag} row {r}: single");
            assert_eq!(
                (b_batch[r], b_steps[r] as usize),
                want,
                "{tag} row {r}: batch vs single"
            );
        }
    }
    drop(bundle);
    let _ = std::fs::remove_file(&fab_path);
    for p in &fdd_paths {
        let _ = std::fs::remove_file(p);
    }
}

/// When XLA artifacts exist, the tensorised backend joins the same
/// conformance check through the same trait object path.
#[test]
fn xla_backend_conforms_when_artifacts_exist() {
    if !std::path::Path::new("artifacts/index.json").exists() {
        eprintln!("skipping xla conformance: run `make artifacts` first");
        return;
    }
    let data = datasets::iris();
    // small variant geometry: 32 trees, depth 6
    let forest = ForestLearner::default()
        .trees(32)
        .max_depth(6)
        .seed(11)
        .fit(&data);
    let dd = ForestCompiler::new(CompileOptions::default())
        .compile(&forest)
        .unwrap();
    let xla = match forest_add::serve::xla_backend::XlaBackend::start("artifacts", "small", &forest)
    {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping xla conformance: backend unavailable: {e}");
            return;
        }
    };
    let registry = ModelRegistry::new();
    registry
        .register(
            "default",
            data.schema.clone(),
            vec![
                (
                    BackendKind::Forest,
                    Arc::new(forest) as Arc<dyn Classifier>,
                ),
                (BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>),
                (BackendKind::Xla, Arc::new(xla) as Arc<dyn Classifier>),
            ],
        )
        .unwrap();
    let version = registry.get(None).unwrap();
    let rows = data.matrix();
    let reference = version
        .slot(BackendKind::Forest)
        .unwrap()
        .classifier
        .classify_batch(rows)
        .unwrap();
    for kind in [BackendKind::Dd, BackendKind::Xla] {
        let got = version
            .slot(kind)
            .unwrap()
            .classifier
            .classify_batch(rows)
            .unwrap();
        assert_eq!(got, reference, "backend {}", kind.name());
    }
}

/// A tripped backend breaker must degrade *bit-identically*: with the
/// primary backend's breaker open, the router's frozen → dd → forest
/// fallback chain serves the same class, label and §6 step count the
/// primary would have served — single-row and batch paths, on every
/// built-in dataset. Degradation is a routing change, never a semantic
/// one.
#[test]
fn breaker_fallback_serves_bit_identical_answers() {
    use forest_add::serve::batcher::BatcherConfig;
    use forest_add::serve::breaker::BreakerBoard;
    use forest_add::serve::metrics::ServerMetrics;
    use forest_add::serve::router::Router;
    use forest_add::serve::ClassifyRequest;
    use std::time::Duration;

    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        let forest = ForestLearner::default().trees(8).seed(31).fit(&data);
        let dd = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap();
        let frozen = dd.freeze();
        let registry = Arc::new(ModelRegistry::new());
        registry
            .register(
                "default",
                data.schema.clone(),
                vec![
                    (
                        BackendKind::Forest,
                        Arc::new(forest) as Arc<dyn Classifier>,
                    ),
                    (BackendKind::Dd, Arc::new(dd) as Arc<dyn Classifier>),
                    (
                        BackendKind::Frozen,
                        Arc::new(frozen) as Arc<dyn Classifier>,
                    ),
                ],
            )
            .unwrap();
        // threshold 1, hour-long cooldown: one recorded failure keeps the
        // dd breaker open for the whole sweep (no half-open probes).
        let router = Router::new(
            registry,
            Arc::new(ServerMetrics::default()),
            BackendKind::Dd,
            BatcherConfig::default(),
            Duration::from_secs(5),
            BreakerBoard::new(1, Duration::from_secs(3600)),
        );
        let rows = data.matrix();

        // healthy answers off the primary path first
        let healthy: Vec<_> = rows
            .iter()
            .map(|row| router.classify(&ClassifyRequest::new(row.to_vec())).unwrap())
            .collect();
        for (i, r) in healthy.iter().enumerate() {
            assert_eq!(r.backend, BackendKind::Dd, "{name} row {i}: primary");
            assert_eq!(r.served_by, None, "{name} row {i}: not degraded yet");
        }
        let healthy_batch = router.classify_batch(rows, None, None, true, false).unwrap();
        assert!(healthy_batch.rerouted.is_none(), "{name}: healthy batch");

        router.breakers().record_failure("default@v1", BackendKind::Dd);
        assert_eq!(router.breakers().open_count(), 1, "{name}: breaker open");

        for (i, row) in rows.iter().enumerate() {
            let got = router.classify(&ClassifyRequest::new(row.to_vec())).unwrap();
            assert_eq!(
                got.backend,
                BackendKind::Frozen,
                "{name} row {i}: fallback backend"
            );
            assert_eq!(
                got.served_by,
                Some(BackendKind::Frozen),
                "{name} row {i}: degraded marker"
            );
            assert_eq!(got.class, healthy[i].class, "{name} row {i}: class");
            assert_eq!(got.steps, healthy[i].steps, "{name} row {i}: §6 steps");
            assert_eq!(got.label, healthy[i].label, "{name} row {i}: label");
        }
        let degraded = router.classify_batch(rows, None, None, true, false).unwrap();
        assert_eq!(
            degraded.rerouted,
            Some(BackendKind::Frozen),
            "{name}: degraded batch marker"
        );
        assert_eq!(
            degraded.classes, healthy_batch.classes,
            "{name}: degraded batch classes"
        );
        assert_eq!(
            degraded.steps, healthy_batch.steps,
            "{name}: degraded batch steps"
        );
    }
}

/// Explicit-SIMD kernels, freeze-time column packing and f16 threshold
/// quantisation are perf features, never semantic ones: every kernel
/// this host can execute × every freeze layout × every tile budget must
/// be bit-identical — class *and* §6 step count, single-threaded
/// kernel-pinned sweeps *and* the sharded ambient entry points — to the
/// single-row walk, on every built-in dataset. Batches carry injected
/// NaN cells: missing-value traffic must take the `lo` edge in both the
/// scalar compare and the masked lane compare.
#[test]
fn simd_kernels_and_freeze_layouts_conform_on_every_dataset() {
    use forest_add::batch::RowMatrix;
    use forest_add::frozen::FreezeOpts;
    use forest_add::runtime::simd;
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        let forest = ForestLearner::default().trees(8).seed(17).fit(&data);
        let dd = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap();

        // 1024 rows (past the sharding crossover) with a NaN injected on
        // every 17th row, walking across the feature columns.
        let nf = data.n_features();
        let tiled = forest_add::bench_support::tile_rows(&data, 1024, 7);
        let mut cells = tiled.as_matrix().data().to_vec();
        for r in (0..1024usize).step_by(17) {
            cells[r * nf + r % nf] = f32::NAN;
        }
        let rows = RowMatrix::new(&cells, nf).unwrap();

        let plain = dd.freeze();
        let mut variants: Vec<(&str, FrozenDD)> = vec![("plain", plain.clone())];
        for (vname, opts) in [
            ("packed", FreezeOpts { pack_features: true, quantize_f16: false }),
            ("f16", FreezeOpts { pack_features: false, quantize_f16: true }),
            ("packed+f16", FreezeOpts { pack_features: true, quantize_f16: true }),
        ] {
            // Every built-in dataset has coarse-granularity thresholds;
            // a refusal here means the f16 widening guard regressed.
            let f = dd
                .freeze_with(opts)
                .unwrap_or_else(|e| panic!("{name}/{vname}: optimised freeze refused: {e}"));
            variants.push((vname, f));
        }

        // truth: the scalar single-row walk on the plain layout
        let reference: Vec<(u32, usize)> =
            rows.iter().map(|x| plain.classify_with_steps(x)).collect();

        let mut scratch = forest_add::frozen::BatchScratch::new();
        let (mut out, mut steps) = (Vec::new(), Vec::new());
        for (vname, frozen) in &variants {
            let tag = format!("{name}/{vname}");
            for (i, x) in rows.iter().enumerate() {
                assert_eq!(
                    frozen.classify_with_steps(x),
                    reference[i],
                    "{tag} row {i}: single-row walk"
                );
            }
            // sharded ambient entry points (multi-threaded on multi-core
            // hosts, whatever kernel the host detects)
            let sharded = frozen.classify_batch(rows);
            let (sharded_classes, sharded_steps) = frozen.classify_batch_steps(rows);
            for (i, want) in reference.iter().enumerate() {
                assert_eq!(sharded[i], want.0, "{tag} row {i}: sharded batch");
                assert_eq!(sharded_classes[i], want.0, "{tag} row {i}: sharded steps batch");
                assert_eq!(
                    sharded_steps[i] as usize, want.1,
                    "{tag} row {i}: sharded batch steps"
                );
            }
            // every executable kernel × every tile budget, kernel-pinned
            // and single-threaded (1 forces minimum tiles, 0 = auto)
            for kernel in simd::available() {
                for tile_budget in [1usize, 4096, 0] {
                    let ktag = format!("{tag}/{}/budget {tile_budget}", kernel.name());
                    frozen.classify_batch_kernel_into(
                        rows,
                        &mut scratch,
                        &mut out,
                        tile_budget,
                        kernel,
                    );
                    for (i, want) in reference.iter().enumerate() {
                        assert_eq!(out[i], want.0, "{ktag} row {i}: classes");
                    }
                    frozen.classify_batch_steps_kernel_into(
                        rows,
                        &mut scratch,
                        &mut out,
                        &mut steps,
                        tile_budget,
                        kernel,
                    );
                    for (i, want) in reference.iter().enumerate() {
                        assert_eq!(out[i], want.0, "{ktag} row {i}: steps-path classes");
                        assert_eq!(steps[i] as usize, want.1, "{ktag} row {i}: steps");
                    }
                }
            }
        }
    }
}

/// Vote-vector conformance: on every built-in dataset, the full
/// per-class distribution a terminal carries — not just its argmax —
/// must be bit-identical between the forest tally, the live DD walk,
/// the frozen sweep, and the snapshot-roundtripped artifact, for both
/// vote-preserving abstractions, across every SIMD kernel this host can
/// execute × every tile budget, single-row and sharded batch paths. The
/// decided class must equal the argmax of the reported distribution
/// (shared tie rule: lowest index), and the majority abstraction must
/// refuse with an error rather than fabricate a distribution.
#[test]
fn vote_distributions_conform_on_every_dataset() {
    use forest_add::add::terminal::argmax;
    use forest_add::runtime::simd;
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        let forest = ForestLearner::default().trees(8).seed(23).fit(&data);
        let rows = data.matrix();
        let k = data.schema.n_classes();

        // truth: the forest's per-row vote tally (always sums to |T|)
        let reference: Vec<Vec<u32>> = rows.iter().map(|x| forest.votes(x)).collect();
        for (i, v) in reference.iter().enumerate() {
            assert_eq!(v.len(), k, "{name} row {i}: tally arity");
            assert_eq!(v.iter().sum::<u32>(), 8, "{name} row {i}: votes sum to |T|");
        }
        let forest_flat = Classifier::votes_batch(&forest, rows).unwrap();
        for (i, want) in reference.iter().enumerate() {
            assert_eq!(
                &forest_flat[i * k..(i + 1) * k],
                &want[..],
                "{name} row {i}: forest batch tally"
            );
        }

        for abstraction in [Abstraction::Word, Abstraction::Vector] {
            let tag = format!("{name}/{abstraction:?}");
            let dd = ForestCompiler::new(CompileOptions {
                abstraction,
                ..Default::default()
            })
            .compile(&forest)
            .unwrap();
            let frozen = dd.freeze();
            let reloaded = FrozenDD::from_bytes(&frozen.to_bytes()).unwrap();
            for (i, x) in rows.iter().enumerate() {
                let want = &reference[i];
                assert_eq!(&dd.votes(x).unwrap(), want, "{tag} row {i}: dd walk");
                assert_eq!(&frozen.votes(x).unwrap(), want, "{tag} row {i}: frozen walk");
                assert_eq!(
                    &reloaded.votes(x).unwrap(),
                    want,
                    "{tag} row {i}: snapshot round-trip"
                );
                // the decision is a pure post-map over the distribution
                assert_eq!(
                    u32::from(argmax(want)),
                    Classifier::classify(&frozen, x).unwrap(),
                    "{tag} row {i}: class != argmax(votes)"
                );
            }
            // flat batch distributions through the trait and the sweeps
            let dd_flat = Classifier::votes_batch(&dd, rows).unwrap();
            let frozen_flat = frozen.votes_batch(rows).unwrap();
            assert_eq!(dd_flat, forest_flat, "{tag}: dd batch distributions");
            assert_eq!(frozen_flat, forest_flat, "{tag}: frozen batch distributions");
            // every executable kernel × every tile budget, kernel-pinned
            // and single-threaded (1 forces minimum tiles, 0 = auto)
            let mut scratch = forest_add::frozen::BatchScratch::new();
            for kernel in simd::available() {
                for tile_budget in [1usize, 4096, 0] {
                    let got = frozen
                        .votes_batch_kernel(rows, &mut scratch, tile_budget, kernel)
                        .unwrap();
                    assert_eq!(
                        got,
                        forest_flat,
                        "{tag}/{}/budget {tile_budget}: kernel-pinned distributions",
                        kernel.name()
                    );
                }
            }
            // past the sharding crossover the sharded sweep must expand
            // exactly the same terminals
            let tiled = forest_add::bench_support::tile_rows(&data, 1024, 7);
            let big = tiled.as_matrix();
            let big_votes = frozen.votes_batch(big).unwrap();
            for (i, x) in big.iter().enumerate() {
                assert_eq!(
                    &big_votes[i * k..(i + 1) * k],
                    &forest.votes(x)[..],
                    "{tag} row {i}: sharded batch distributions"
                );
            }
        }

        // the majority abstraction folded the payload at compile time:
        // asking for it is a capability error, never a made-up vector
        let majority = ForestCompiler::new(CompileOptions::default())
            .compile(&forest)
            .unwrap();
        let err = Classifier::votes(&majority, rows.row(0)).unwrap_err();
        assert!(err.to_string().contains("vote"), "{name}: {err}");
        let err = majority.freeze().votes_batch(rows).unwrap_err();
        assert!(err.to_string().contains("vote"), "{name}: {err}");
    }
}

/// Regression conformance: a binned-target forest predicts the same
/// vote-weighted mean through every backend, because the value table is
/// a schema-level post-map over the same conformant distributions.
#[test]
fn regression_values_conform_across_backends() {
    use forest_add::add::terminal::expected_value;
    use forest_add::data::synth::{regression, RegressionSpec};
    let data = regression(&RegressionSpec {
        rows: 160,
        bins: 8,
        ..Default::default()
    })
    .unwrap();
    let values = data.schema.values().expect("regression schema").to_vec();
    let forest = ForestLearner::default().trees(9).seed(37).fit(&data);
    let dd = ForestCompiler::new(CompileOptions {
        abstraction: Abstraction::Vector,
        ..Default::default()
    })
    .compile(&forest)
    .unwrap();
    let frozen = dd.freeze();
    let reloaded = FrozenDD::from_bytes(&frozen.to_bytes()).unwrap();
    // the value table survives the snapshot round-trip bit-identically
    assert_eq!(reloaded.task_values().as_deref(), Some(&values[..]));
    let rows = data.matrix();
    for (i, x) in rows.iter().enumerate() {
        let want = expected_value(&forest.votes(x), &values);
        assert!(want.is_finite(), "row {i}: reference value");
        for (label, votes) in [
            ("dd", dd.votes(x).unwrap()),
            ("frozen", frozen.votes(x).unwrap()),
            ("snapshot", reloaded.votes(x).unwrap()),
        ] {
            let got = expected_value(&votes, &values);
            assert_eq!(got.to_bits(), want.to_bits(), "{label} row {i}: value");
        }
    }
}

/// Sharded-parallel batch evaluation must be bit-identical to the
/// single-threaded per-row path for every backend × abstraction ×
/// dataset. Batches are tiled far past both the frozen sweep's
/// batch-vs-walk threshold and the multi-core sharding crossover, so the
/// parallel code genuinely runs (on multi-core hosts) and its contiguous
/// shard/disjoint-output scheme is pinned against the serial truth.
#[test]
fn sharded_batches_are_bit_identical_to_single_thread() {
    for name in datasets::names() {
        let data = datasets::load(name).unwrap();
        let (registry, names) = registry_for(&data, 6, 29).unwrap();
        // Tile to 2048 rows (≥ every backend's parallel crossover).
        let tiled = forest_add::bench_support::tile_rows(&data, 2048, 13);
        let rows = tiled.as_matrix();
        for model in &names {
            let (_, slot) = registry.resolve(Some(model.as_str()), None).unwrap();
            let c = slot.classifier.as_ref();
            let batch = c.classify_batch(rows).unwrap();
            assert_eq!(batch.len(), rows.n_rows());
            // the metered batch path shards identically
            let (steps_batch, steps) = c.classify_batch_with_steps(rows).unwrap();
            assert_eq!(steps_batch, batch, "{name}/{model}: steps batch classes");
            let steps = steps.expect("native backends meter steps");
            // serial truth: one classify per row through the same trait
            for (i, row) in rows.iter().enumerate() {
                let (want_class, want_steps) = c.classify_with_steps(row).unwrap();
                assert_eq!(
                    batch[i], want_class,
                    "{name}/{model} row {i}: sharded batch diverged"
                );
                assert_eq!(
                    steps[i] as usize,
                    want_steps.unwrap(),
                    "{name}/{model} row {i}: sharded batch steps diverged"
                );
            }
        }
    }
}
