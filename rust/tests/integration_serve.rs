//! Integration: the serving coordinator over real sockets — lifecycle,
//! every endpoint, backend agreement, concurrency, and error handling.

use forest_add::serve::config::ServeConfig;
use forest_add::serve::http::http_request;
use forest_add::serve::server;
use forest_add::data::datasets;
use forest_add::util::json::{self, Json};

fn test_config() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        dataset: "iris".into(),
        trees: 32,
        max_depth: 6,
        seed: 7,
        variant: "small".into(),
        enable_xla: std::path::Path::new("artifacts/index.json").exists(),
        http_workers: 3,
        ..Default::default()
    }
}

fn row_json(row: &[f32]) -> Json {
    Json::Arr(row.iter().map(|&v| json::num(v as f64)).collect())
}

#[test]
fn full_server_lifecycle_and_endpoints() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let data = datasets::load("iris").unwrap();

    // healthz
    let (st, body) = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(st, 200);
    assert_eq!(body.get("ok").and_then(Json::as_bool), Some(true));

    // model info
    let (st, model) = http_request(&addr, "GET", "/model", None).unwrap();
    assert_eq!(st, 200);
    assert_eq!(model.get_i64("trees"), Some(32));
    assert!(model.get_i64("dd_nodes").unwrap() > 0);
    // (the size crossover below the forest happens at larger tree counts —
    // Fig. 7; here we only require a sane envelope)
    assert!(model.get_i64("dd_nodes").unwrap() < model.get_i64("forest_nodes").unwrap() * 20);

    // classify on both native backends, agreement with the local forest
    for backend in ["forest", "dd"] {
        for i in [0usize, 60, 149] {
            let body = json::obj(vec![
                ("features", row_json(data.row(i))),
                ("backend", json::s(backend)),
            ]);
            let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
            assert_eq!(st, 200, "{resp:?}");
            let class = resp.get_i64("class").unwrap() as u32;
            assert_eq!(
                class,
                handle.router.bundle().forest.predict(data.row(i)),
                "backend {backend} row {i}"
            );
            assert!(resp.get_i64("steps").is_some());
            assert!(!resp.get_str("label").unwrap().is_empty());
        }
    }

    // xla backend end-to-end when artifacts exist
    if handle.router.has_xla() {
        let body = json::obj(vec![
            ("features", row_json(data.row(25))),
            ("backend", json::s("xla")),
        ]);
        let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
        assert_eq!(st, 200, "{resp:?}");
        assert_eq!(
            resp.get_i64("class").unwrap() as u32,
            handle.router.bundle().forest.predict(data.row(25))
        );
        assert_eq!(resp.get("steps"), Some(&Json::Null));
    }

    // batch endpoint
    let rows: Vec<Json> = (0..10).map(|i| row_json(data.row(i * 14))).collect();
    let body = json::obj(vec![("rows", Json::Arr(rows))]);
    let (st, resp) = http_request(&addr, "POST", "/classify_batch", Some(&body)).unwrap();
    assert_eq!(st, 200);
    assert_eq!(resp.get("classes").unwrap().as_arr().unwrap().len(), 10);
    assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 10);

    // metrics reflect the traffic
    let (st, metrics) = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(st, 200);
    assert!(metrics.get_i64("requests").unwrap() >= 7);
    assert_eq!(metrics.get_i64("errors"), Some(0));

    handle.stop();
}

#[test]
fn error_handling_over_http() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();

    // wrong arity
    let body = json::obj(vec![("features", row_json(&[1.0, 2.0]))]);
    let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 400);
    assert!(resp.get_str("error").unwrap().contains("features"));

    // malformed JSON
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    use std::io::{Read, Write};
    let junk = "POST /classify HTTP/1.1\r\nContent-Length: 5\r\n\r\n{{{{{";
    stream.write_all(junk.as_bytes()).unwrap();
    let mut out = String::new();
    stream.read_to_string(&mut out).unwrap();
    assert!(out.starts_with("HTTP/1.1 400"), "{out}");

    // unknown path and wrong method
    let (st, _) = http_request(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(st, 404);
    let (st, _) = http_request(&addr, "DELETE", "/classify", None).unwrap();
    assert_eq!(st, 405);

    // unknown backend string
    let data = datasets::load("iris").unwrap();
    let body = json::obj(vec![
        ("features", row_json(data.row(0))),
        ("backend", json::s("quantum")),
    ]);
    let (st, _) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 400);

    // empty batch
    let body = json::obj(vec![("rows", Json::Arr(vec![]))]);
    let (st, _) = http_request(&addr, "POST", "/classify_batch", Some(&body)).unwrap();
    assert_eq!(st, 400);

    handle.stop();
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let data = datasets::load("iris").unwrap();
    let forest = &handle.router.bundle().forest;
    let expected: Vec<u32> = (0..data.n_rows()).map(|i| forest.predict(data.row(i))).collect();

    std::thread::scope(|scope| {
        for c in 0..6 {
            let addr = addr.clone();
            let data = &data;
            let expected = &expected;
            scope.spawn(move || {
                for i in (c..data.n_rows()).step_by(6) {
                    let backend = if i % 2 == 0 { "dd" } else { "forest" };
                    let body = json::obj(vec![
                        ("features", row_json(data.row(i))),
                        ("backend", json::s(backend)),
                    ]);
                    let (st, resp) =
                        http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
                    assert_eq!(st, 200);
                    assert_eq!(resp.get_i64("class").unwrap() as u32, expected[i], "row {i}");
                }
            });
        }
    });

    let (_, metrics) = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(metrics.get_i64("requests"), Some(150));
    assert_eq!(metrics.get_i64("errors"), Some(0));
    handle.stop();
}

#[test]
fn xla_fallback_when_forest_incompatible() {
    // 33 trees do not divide the small variant's 32 slots -> the server must
    // fall back to native backends instead of failing or mis-serving.
    let cfg = ServeConfig {
        trees: 33,
        ..test_config()
    };
    let handle = server::start(&cfg).unwrap();
    assert!(!handle.router.has_xla());
    let data = datasets::load("iris").unwrap();
    let addr = handle.addr.to_string();
    let body = json::obj(vec![("features", row_json(data.row(0)))]);
    let (st, _) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 200, "dd backend still serves");
    handle.stop();
}
