//! Integration: the serving coordinator over real sockets — lifecycle,
//! every endpoint, backend agreement, model hot-swap, concurrency, and
//! error handling.

use forest_add::classifier::Classifier;
use forest_add::data::datasets;
use forest_add::engine::Engine;
use forest_add::serve::config::ServeConfig;
use forest_add::serve::http::http_request;
use forest_add::serve::{server, BackendKind};
use forest_add::util::json::{self, Json};
use std::sync::Arc;

fn test_config() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        dataset: "iris".into(),
        trees: 32,
        max_depth: 6,
        seed: 7,
        variant: "small".into(),
        enable_xla: std::path::Path::new("artifacts/index.json").exists(),
        http_workers: 3,
        ..Default::default()
    }
}

fn row_json(row: &[f32]) -> Json {
    Json::Arr(row.iter().map(|&v| json::num(v as f64)).collect())
}

/// The forest backend of the default model, resolved the way every
/// request is: as a `Classifier` trait object from the registry.
fn forest_of(handle: &server::ServerHandle) -> Arc<dyn Classifier> {
    let (_, slot) = handle
        .router
        .registry()
        .resolve(None, Some(BackendKind::Forest))
        .unwrap();
    slot.classifier
}

#[test]
fn full_server_lifecycle_and_endpoints() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let data = datasets::load("iris").unwrap();
    let reference = forest_of(&handle);

    // healthz
    let (st, body) = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(st, 200);
    assert_eq!(body.get("ok").and_then(Json::as_bool), Some(true));

    // model info: name@version plus per-backend size/cost metadata
    let (st, model) = http_request(&addr, "GET", "/model", None).unwrap();
    assert_eq!(st, 200);
    assert_eq!(model.get_str("model"), Some("default"));
    assert_eq!(model.get_i64("version"), Some(1));
    let backends = model.get("backends").and_then(Json::as_arr).unwrap();
    assert!(backends.len() >= 2);
    let size_of = |name: &str| {
        backends
            .iter()
            .find(|b| b.get_str("backend") == Some(name))
            .and_then(|b| b.get_i64("size_nodes"))
            .unwrap()
    };
    assert!(size_of("forest") > 0);
    assert!(size_of("dd") > 0);
    assert_eq!(size_of("frozen"), size_of("dd"), "freezing preserves size");
    // (the size crossover below the forest happens at larger tree counts —
    // Fig. 7; here we only require a sane envelope)
    assert!(size_of("dd") < size_of("forest") * 20);

    // models listing
    let (st, models) = http_request(&addr, "GET", "/models", None).unwrap();
    assert_eq!(st, 200);
    assert_eq!(models.get_str("default_model"), Some("default"));
    assert_eq!(models.get("models").and_then(Json::as_arr).unwrap().len(), 1);

    // classify on every native backend, agreement with the reference
    // forest classifier
    for backend in ["forest", "dd", "frozen"] {
        for i in [0usize, 60, 149] {
            let body = json::obj(vec![
                ("features", row_json(data.row(i))),
                ("backend", json::s(backend)),
            ]);
            let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
            assert_eq!(st, 200, "{resp:?}");
            let class = resp.get_i64("class").unwrap() as u32;
            assert_eq!(
                class,
                reference.classify(data.row(i)).unwrap(),
                "backend {backend} row {i}"
            );
            assert!(resp.get_i64("steps").is_some());
            assert!(!resp.get_str("label").unwrap().is_empty());
            assert_eq!(resp.get_str("model"), Some("default@v1"));
        }
    }

    // xla backend end-to-end when artifacts exist
    if handle.router.has_xla() {
        let body = json::obj(vec![
            ("features", row_json(data.row(25))),
            ("backend", json::s("xla")),
        ]);
        let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
        assert_eq!(st, 200, "{resp:?}");
        assert_eq!(
            resp.get_i64("class").unwrap() as u32,
            reference.classify(data.row(25)).unwrap()
        );
        assert_eq!(resp.get("steps"), Some(&Json::Null));
    }

    // batch endpoint
    let rows: Vec<Json> = (0..10).map(|i| row_json(data.row(i * 14))).collect();
    let body = json::obj(vec![("rows", Json::Arr(rows))]);
    let (st, resp) = http_request(&addr, "POST", "/classify_batch", Some(&body)).unwrap();
    assert_eq!(st, 200);
    assert_eq!(resp.get("classes").unwrap().as_arr().unwrap().len(), 10);
    assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 10);

    // metrics reflect the traffic
    let (st, metrics) = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(st, 200);
    assert!(metrics.get_i64("requests").unwrap() >= 7);
    assert_eq!(metrics.get_i64("errors"), Some(0));

    handle.stop();
}

#[test]
fn serve_from_snapshot_skips_training() {
    // Build the artifact the way a deploy pipeline would …
    let data = datasets::load("iris").unwrap();
    let forest = forest_add::forest::ForestLearner::default()
        .trees(24)
        .seed(3)
        .fit(&data);
    let frozen = forest_add::compile::ForestCompiler::default()
        .compile_frozen(&forest)
        .unwrap();
    let path = std::env::temp_dir().join(format!("serve-snap-{}.fdd", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    frozen.save(&path_s).unwrap();

    // … then boot a replica from it: no dataset, no training.
    let cfg = ServeConfig {
        snapshot: path_s,
        dataset: String::new(),
        ..test_config()
    };
    let handle = server::start(&cfg).unwrap();
    let addr = handle.addr.to_string();

    // untagged traffic lands on the frozen backend (the model's only one)
    for i in [0usize, 75, 149] {
        let body = json::obj(vec![("features", row_json(data.row(i)))]);
        let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
        assert_eq!(st, 200, "{resp:?}");
        assert_eq!(resp.get_str("backend"), Some("frozen"));
        assert_eq!(resp.get_i64("class").unwrap() as u32, frozen.classify(data.row(i)));
        assert!(resp.get_i64("steps").is_some(), "frozen walks are metered");
    }

    // the batch endpoint exercises the node-array pass; `steps: true`
    // carries the §6 metering through the batch path
    let rows: Vec<Json> = (0..20).map(|i| row_json(data.row(i * 7))).collect();
    let body = json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("steps", Json::Bool(true)),
    ]);
    let (st, resp) = http_request(&addr, "POST", "/classify_batch", Some(&body)).unwrap();
    assert_eq!(st, 200);
    let classes = resp.get("classes").unwrap().as_arr().unwrap();
    let steps = resp.get("steps").unwrap().as_arr().unwrap();
    assert_eq!(steps.len(), classes.len());
    for (k, (c, s)) in classes.iter().zip(steps).enumerate() {
        let (want_class, want_steps) = frozen.classify_with_steps(data.row(k * 7));
        assert_eq!(c.as_i64().unwrap() as u32, want_class, "batch row {k}");
        assert_eq!(s.as_i64().unwrap() as usize, want_steps, "batch row {k} steps");
    }

    // /model reports the frozen backend
    let (_, model) = http_request(&addr, "GET", "/model", None).unwrap();
    let backends = model.get("backends").and_then(Json::as_arr).unwrap();
    assert_eq!(backends.len(), 1);
    assert_eq!(backends[0].get_str("backend"), Some("frozen"));

    handle.stop();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_from_bundle_routes_per_model() {
    // Deploy pipeline: two distinct models packed into one fab artifact.
    let iris = datasets::load("iris").unwrap();
    let lenses = datasets::load("lenses").unwrap();
    let builder = Engine::new();
    builder
        .train_and_register(
            "iris",
            &iris,
            16,
            0,
            3,
            forest_add::compile::CompileOptions::default(),
        )
        .unwrap();
    builder
        .train_and_register(
            "lenses",
            &lenses,
            8,
            0,
            5,
            forest_add::compile::CompileOptions::default(),
        )
        .unwrap();
    let path = std::env::temp_dir().join(format!("serve-bundle-{}.fab", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    builder.save_bundle(&[], &path_s).unwrap();
    let frozen_class = |model: &str, row: &[f32]| {
        builder
            .classify(Some(model), Some(BackendKind::Frozen), row)
            .unwrap()
    };

    // Fleet replica: one artifact, every model, no training.
    let cfg = ServeConfig {
        bundle: path_s,
        dataset: String::new(),
        ..test_config()
    };
    let handle = server::start(&cfg).unwrap();
    let addr = handle.addr.to_string();

    // /models lists both entries with their bundle provenance
    let (st, models) = http_request(&addr, "GET", "/models", None).unwrap();
    assert_eq!(st, 200);
    let list = models.get("models").and_then(Json::as_arr).unwrap();
    assert_eq!(list.len(), 2);
    for m in list {
        let source = m.get_str("source").expect("bundle models carry provenance");
        assert!(source.contains(".fab#"), "{source}");
        let backends = m.get("backends").and_then(Json::as_arr).unwrap();
        assert_eq!(backends.len(), 1);
        assert_eq!(backends[0].as_str(), Some("frozen"));
    }
    // manifest order: the first entry is the default model
    assert_eq!(models.get_str("default_model"), Some("iris"));

    // per-request `model` routes into the right bundle entry
    for (name, ds) in [("iris", &iris), ("lenses", &lenses)] {
        for i in [0usize, ds.n_rows() / 2, ds.n_rows() - 1] {
            let body = json::obj(vec![
                ("features", row_json(ds.row(i))),
                ("model", json::s(name)),
            ]);
            let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
            assert_eq!(st, 200, "{resp:?}");
            assert_eq!(resp.get_str("model"), Some(format!("{name}@v1").as_str()));
            assert_eq!(resp.get_str("backend"), Some("frozen"));
            assert_eq!(
                resp.get_i64("class").unwrap() as u32,
                frozen_class(name, ds.row(i)),
                "{name} row {i}"
            );
        }
    }
    // untagged traffic lands on the first bundle entry
    let body = json::obj(vec![("features", row_json(iris.row(0)))]);
    let (_, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(resp.get_str("model"), Some("iris@v1"));
    // wrong-arity requests against a named bundle model fail cleanly
    let body = json::obj(vec![
        ("features", row_json(iris.row(0))),
        ("model", json::s("lenses")),
    ]);
    let (st, _) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 400, "iris arity against the lenses model");

    handle.stop();
    let _ = std::fs::remove_file(&path);

    // a config naming both snapshot and bundle is rejected up front
    let bad = ServeConfig {
        snapshot: "x.fdd".into(),
        bundle: "y.fab".into(),
        ..test_config()
    };
    assert!(server::start(&bad).is_err());
}

#[test]
fn error_handling_over_http() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();

    // wrong arity
    let body = json::obj(vec![("features", row_json(&[1.0, 2.0]))]);
    let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 400);
    assert!(resp.get_str("error").unwrap().contains("features"));

    // malformed JSON
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    use std::io::{Read, Write};
    let junk = "POST /classify HTTP/1.1\r\nContent-Length: 5\r\n\r\n{{{{{";
    stream.write_all(junk.as_bytes()).unwrap();
    let mut out = String::new();
    stream.read_to_string(&mut out).unwrap();
    assert!(out.starts_with("HTTP/1.1 400"), "{out}");

    // unknown path and wrong method
    let (st, _) = http_request(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(st, 404);
    let (st, _) = http_request(&addr, "DELETE", "/classify", None).unwrap();
    assert_eq!(st, 405);

    // unknown backend string
    let data = datasets::load("iris").unwrap();
    let body = json::obj(vec![
        ("features", row_json(data.row(0))),
        ("backend", json::s("quantum")),
    ]);
    let (st, _) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 400);

    // unknown model name
    let body = json::obj(vec![
        ("features", row_json(data.row(0))),
        ("model", json::s("phantom")),
    ]);
    let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 400);
    assert!(resp.get_str("error").unwrap().contains("phantom"));

    // empty batch
    let body = json::obj(vec![("rows", Json::Arr(vec![]))]);
    let (st, _) = http_request(&addr, "POST", "/classify_batch", Some(&body)).unwrap();
    assert_eq!(st, 400);

    handle.stop();
}

#[test]
fn model_hot_swap_is_visible_to_live_traffic() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let data = datasets::load("iris").unwrap();

    // register a second version of "default" and a named canary model on
    // the running server's registry — no restart
    let engine = Engine::with_registry(handle.router.registry().clone());
    engine
        .train_and_register(
            "default",
            &data,
            16,
            0,
            99,
            forest_add::compile::CompileOptions::default(),
        )
        .unwrap();
    engine
        .train_and_register(
            "canary",
            &data,
            8,
            0,
            5,
            forest_add::compile::CompileOptions::default(),
        )
        .unwrap();

    // untagged traffic now lands on default@v2
    let body = json::obj(vec![("features", row_json(data.row(3)))]);
    let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 200, "{resp:?}");
    assert_eq!(resp.get_str("model"), Some("default@v2"));

    // tagged traffic reaches the canary
    let body = json::obj(vec![
        ("features", row_json(data.row(3))),
        ("model", json::s("canary")),
    ]);
    let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 200, "{resp:?}");
    assert_eq!(resp.get_str("model"), Some("canary@v1"));

    // the listing shows both
    let (_, models) = http_request(&addr, "GET", "/models", None).unwrap();
    let names: Vec<&str> = models
        .get("models")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(|m| m.get_str("name"))
        .collect();
    assert!(names.contains(&"default") && names.contains(&"canary"), "{names:?}");

    handle.stop();
}

#[test]
fn concurrent_clients_all_served_correctly() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let data = datasets::load("iris").unwrap();
    let forest = forest_of(&handle);
    let expected: Vec<u32> = (0..data.n_rows())
        .map(|i| forest.classify(data.row(i)).unwrap())
        .collect();

    std::thread::scope(|scope| {
        for c in 0..6 {
            let addr = addr.clone();
            let data = &data;
            let expected = &expected;
            scope.spawn(move || {
                for i in (c..data.n_rows()).step_by(6) {
                    let backend = if i % 2 == 0 { "dd" } else { "forest" };
                    let body = json::obj(vec![
                        ("features", row_json(data.row(i))),
                        ("backend", json::s(backend)),
                    ]);
                    let (st, resp) =
                        http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
                    assert_eq!(st, 200);
                    assert_eq!(resp.get_i64("class").unwrap() as u32, expected[i], "row {i}");
                }
            });
        }
    });

    let (_, metrics) = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(metrics.get_i64("requests"), Some(150));
    assert_eq!(metrics.get_i64("errors"), Some(0));
    handle.stop();
}

#[test]
fn sync_read_timeout_closes_stalled_connections() {
    use std::io::{Read, Write};
    let cfg = ServeConfig {
        io_mode: forest_add::serve::config::IoMode::Sync,
        read_timeout_ms: 300,
        http_workers: 2,
        ..test_config()
    };
    let handle = server::start(&cfg).unwrap();
    let addr = handle.addr.to_string();

    // a client stalled mid-request gets told why (408), promptly
    let mut stalled = std::net::TcpStream::connect(&addr).unwrap();
    stalled
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    stalled.write_all(b"POST /classify HTTP/1.1\r\nConte").unwrap();
    let t0 = std::time::Instant::now();
    let mut out = String::new();
    stalled.read_to_string(&mut out).unwrap();
    assert!(out.starts_with("HTTP/1.1 408"), "{out}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(8),
        "timeout must fire promptly, not at the client's deadline"
    );

    // an idle connection at a request boundary is closed silently
    let mut idle = std::net::TcpStream::connect(&addr).unwrap();
    idle.set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    let mut buf = Vec::new();
    idle.read_to_end(&mut buf).unwrap();
    assert!(buf.is_empty(), "idle close sends no bytes");

    // with only 2 workers, neither stalled client pinned the pool
    let (st, _) = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(st, 200);
    handle.stop();
}

#[test]
fn xla_fallback_when_forest_incompatible() {
    // 33 trees do not divide the small variant's 32 slots -> the server must
    // fall back to native backends instead of failing or mis-serving.
    let cfg = ServeConfig {
        trees: 33,
        ..test_config()
    };
    let handle = server::start(&cfg).unwrap();
    assert!(!handle.router.has_xla());
    let data = datasets::load("iris").unwrap();
    let addr = handle.addr.to_string();
    let body = json::obj(vec![("features", row_json(data.row(0)))]);
    let (st, _) = http_request(&addr, "POST", "/classify", Some(&body)).unwrap();
    assert_eq!(st, 200, "dd backend still serves");
    handle.stop();
}
