//! Integration: the evented networking subsystem against the sync
//! front-end over real sockets — bit-identity under concurrent
//! keep-alive load, the binary row-frame contract, and admission
//! control (`429` + `Retry-After`) when the batcher queue fills.

use forest_add::batch::{RowMatrix, RowMatrixBuf};
use forest_add::classifier::{Classifier, ClassifierInfo, CostModel};
use forest_add::data::datasets;
use forest_add::error::Result;
use forest_add::net::proto;
use forest_add::serve::config::{IoMode, ServeConfig};
use forest_add::serve::http::{http_request, HttpClient};
use forest_add::serve::{server, BackendKind};
use forest_add::util::json::{self, strip_key, Json};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn test_config() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        dataset: "iris".into(),
        trees: 32,
        max_depth: 6,
        seed: 7,
        enable_xla: false,
        ..Default::default()
    }
}

fn row_json(row: &[f32]) -> Json {
    Json::Arr(row.iter().map(|&v| json::num(v as f64)).collect())
}

/// Encode `rows` (borrowed from the dataset) as a binary row frame.
fn frame_of(rows: &[&[f32]]) -> Vec<u8> {
    let mut buf = RowMatrixBuf::with_capacity(rows[0].len(), rows.len());
    for row in rows {
        buf.push_row(row).unwrap();
    }
    proto::encode_rows(buf.as_matrix()).unwrap()
}

/// One of the four request shapes the identity sweep cycles through.
/// Returns `(path, content_type, body)`.
fn mixed_request(
    data: &forest_add::data::Dataset,
    conn: usize,
    seq: usize,
) -> (String, &'static str, Vec<u8>) {
    let n = data.n_rows();
    let i = (conn * 31 + seq * 7) % n;
    let j = (i + 1) % n;
    match seq % 4 {
        0 => (
            "/classify".to_string(),
            "application/json",
            json::obj(vec![("features", row_json(data.row(i)))])
                .to_string_compact()
                .into_bytes(),
        ),
        1 => (
            "/classify".to_string(),
            proto::BINARY_ROWS,
            frame_of(&[data.row(i)]),
        ),
        2 => {
            let rows = Json::Arr(vec![row_json(data.row(i)), row_json(data.row(j))]);
            (
                "/classify_batch".to_string(),
                "application/json",
                json::obj(vec![("rows", rows), ("steps", Json::Bool(true))])
                    .to_string_compact()
                    .into_bytes(),
            )
        }
        _ => (
            "/classify_batch?steps=true".to_string(),
            proto::BINARY_ROWS,
            frame_of(&[data.row(i), data.row(j)]),
        ),
    }
}

/// The acceptance gate of the subsystem: the sync and evented
/// front-ends serve bit-identical responses (latency field aside) to 64
/// concurrent keep-alive connections mixing JSON and binary, single and
/// batch requests.
#[test]
fn sync_and_evented_front_ends_are_bit_identical() {
    if !forest_add::net::poll::supported() {
        eprintln!("skipping: no epoll/kqueue on this target");
        return;
    }
    const CONNS: usize = 64;
    const REQUESTS: usize = 6;
    // identical deterministic models; the sync pool needs one worker per
    // concurrent keep-alive connection, the evented loop does not
    let sync_handle = server::start(&ServeConfig {
        io_mode: IoMode::Sync,
        http_workers: CONNS + 8,
        ..test_config()
    })
    .unwrap();
    let evented_handle = server::start(&ServeConfig {
        io_mode: IoMode::Evented,
        http_workers: 8,
        ..test_config()
    })
    .unwrap();
    let sync_addr = sync_handle.addr.to_string();
    let evented_addr = evented_handle.addr.to_string();
    let data = datasets::load("iris").unwrap();

    std::thread::scope(|scope| {
        for c in 0..CONNS {
            let sync_addr = &sync_addr;
            let evented_addr = &evented_addr;
            let data = &data;
            scope.spawn(move || {
                let mut sync_client = HttpClient::connect(sync_addr).unwrap();
                let mut evented_client = HttpClient::connect(evented_addr).unwrap();
                for r in 0..REQUESTS {
                    let (path, content_type, body) = mixed_request(data, c, r);
                    let (st_s, _, body_s) = sync_client
                        .request_raw("POST", &path, content_type, &body)
                        .unwrap();
                    let (st_e, _, body_e) = evented_client
                        .request_raw("POST", &path, content_type, &body)
                        .unwrap();
                    assert_eq!(st_s, 200, "conn {c} req {r} {path} (sync)");
                    assert_eq!(st_e, 200, "conn {c} req {r} {path} (evented)");
                    let v_s = Json::parse(std::str::from_utf8(&body_s).unwrap()).unwrap();
                    let v_e = Json::parse(std::str::from_utf8(&body_e).unwrap()).unwrap();
                    assert_eq!(
                        strip_key(&v_s, "latency_us"),
                        strip_key(&v_e, "latency_us"),
                        "conn {c} req {r} {path} diverged between front-ends"
                    );
                }
            });
        }
    });

    // both front-ends measured the sweep: end-to-end quantiles are live
    for (addr, mode) in [(&sync_addr, "sync"), (&evented_addr, "evented")] {
        let (st, m) = http_request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(st, 200);
        assert_eq!(m.get_str("io_mode"), Some(mode));
        let req_us = m.get("request_us").unwrap();
        assert!(
            req_us.get_i64("count").unwrap() >= (CONNS * REQUESTS) as i64,
            "{mode}: {req_us:?}"
        );
        for q in ["p50_us", "p95_us", "p99_us"] {
            assert!(req_us.get_i64(q).unwrap() > 0, "{mode} {q}: {req_us:?}");
        }
        let conns = m.get("connections").unwrap();
        assert!(
            conns.get_i64("total").unwrap() >= CONNS as i64,
            "{mode}: {conns:?}"
        );
        assert_eq!(m.get_i64("rejected_429"), Some(0), "{mode}");
    }

    sync_handle.stop();
    evented_handle.stop();
}

/// The wire contract of the binary row frame over HTTP: every
/// malformation is a clean `400` (never a dead server), NaN cells pass
/// through by policy, and `/classify` enforces its exactly-one-row rule.
#[test]
fn malformed_binary_frames_get_400_over_http() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let frame = |n_rows: u32, n_features: u32, cells: &[f32]| -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&n_rows.to_le_bytes());
        out.extend_from_slice(&n_features.to_le_bytes());
        for c in cells {
            out.extend_from_slice(&c.to_le_bytes());
        }
        out
    };
    let cases: Vec<(&str, &str, Vec<u8>)> = vec![
        ("truncated header", "/classify_batch", vec![1, 0, 0]),
        ("zero rows", "/classify_batch", frame(0, 4, &[])),
        ("zero features", "/classify_batch", frame(3, 0, &[])),
        (
            "row-count overflow",
            "/classify_batch",
            frame(u32::MAX, u32::MAX, &[1.0]),
        ),
        (
            "length mismatch",
            "/classify_batch",
            frame(2, 4, &[1.0, 2.0, 3.0, 4.0]),
        ),
        (
            "arity mismatch vs model",
            "/classify_batch",
            frame(2, 2, &[1.0, 2.0, 3.0, 4.0]),
        ),
        (
            "multi-row frame on /classify",
            "/classify",
            frame(2, 4, &[0.1; 8]),
        ),
    ];
    for (name, path, body) in &cases {
        let mut client = HttpClient::connect(&addr).unwrap();
        let (st, _, resp) = client
            .request_raw("POST", path, proto::BINARY_ROWS, body)
            .unwrap();
        assert_eq!(st, 400, "{name}: {}", String::from_utf8_lossy(&resp));
    }
    // NaN cells are accepted by policy (comparisons resolve them downward)
    let mut client = HttpClient::connect(&addr).unwrap();
    let (st, _, resp) = client
        .request_raw(
            "POST",
            "/classify",
            proto::BINARY_ROWS,
            &frame(1, 4, &[f32::NAN, 0.0, 0.0, 0.0]),
        )
        .unwrap();
    assert_eq!(st, 200, "{}", String::from_utf8_lossy(&resp));
    drop(client); // hang up before stop: don't pin a sync worker
    // the server survived every malformation
    let (st, _) = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(st, 200);
    handle.stop();
}

/// A batch-first classifier whose batch evaluation blocks until the
/// gate opens — pins the batcher thread so the bounded queue fills.
struct Gated {
    n_features: usize,
    n_classes: usize,
    gate: Arc<AtomicBool>,
}

impl Classifier for Gated {
    fn info(&self) -> ClassifierInfo {
        ClassifierInfo {
            backend: BackendKind::Xla,
            label: "gated test backend".into(),
            n_features: self.n_features,
            n_classes: self.n_classes,
            size_nodes: 0,
            cost: CostModel {
                max_steps: None,
                aggregation_reads: 0,
                preferred_batch: 64,
            },
        }
    }

    fn classify_with_steps(&self, _x: &[f32]) -> Result<(u32, Option<usize>)> {
        Ok((0, None))
    }

    fn classify_batch(&self, rows: RowMatrix<'_>) -> Result<Vec<u32>> {
        while self.gate.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(vec![0; rows.n_rows()])
    }
}

/// Admission control end to end: a full batcher queue sheds overflow
/// requests with `429` + `Retry-After: 1` instead of queueing them, and
/// the shed count lands in `/metrics`.
#[test]
fn full_batcher_queue_sheds_with_429_and_retry_after() {
    let handle = server::start(&ServeConfig {
        batch_max: 1,
        batch_queue_cap: 1,
        reply_timeout_ms: 30_000,
        http_workers: 16,
        ..test_config()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    // hot-register a batch-first model whose evaluation is gated shut
    let schema = handle.router.registry().get(None).unwrap().schema.clone();
    let gate = Arc::new(AtomicBool::new(true));
    let gated: Arc<dyn Classifier> = Arc::new(Gated {
        n_features: schema.n_features(),
        n_classes: schema.n_classes(),
        gate: gate.clone(),
    });
    handle
        .router
        .registry()
        .register("gated", schema, vec![(BackendKind::Xla, gated)])
        .unwrap();

    let data = datasets::load("iris").unwrap();
    let body = json::obj(vec![
        ("features", row_json(data.row(0))),
        ("model", json::s("gated")),
    ])
    .to_string_compact()
    .into_bytes();

    // 12 concurrent clients race a depth-1 queue behind the blocked
    // batcher: one in flight, one queued, the rest must shed fast
    let results: Vec<(u16, Vec<(String, String)>)> = std::thread::scope(|scope| {
        let clients: Vec<_> = (0..12)
            .map(|_| {
                let addr = &addr;
                let body = &body;
                scope.spawn(move || {
                    let mut client = HttpClient::connect(addr).unwrap();
                    let (st, headers, _) = client
                        .request_raw("POST", "/classify", "application/json", body)
                        .unwrap();
                    (st, headers)
                })
            })
            .collect();
        // let every request land while the gate is shut, then drain
        std::thread::sleep(Duration::from_millis(400));
        gate.store(false, Ordering::SeqCst);
        clients.into_iter().map(|c| c.join().unwrap()).collect()
    });

    let shed: Vec<_> = results.iter().filter(|(st, _)| *st == 429).collect();
    let ok = results.iter().filter(|(st, _)| *st == 200).count();
    assert!(ok >= 1, "in-flight and queued requests must drain: {results:?}");
    assert!(!shed.is_empty(), "overflow must shed with 429: {results:?}");
    for (_, headers) in &shed {
        assert!(
            headers
                .iter()
                .any(|(k, v)| k.eq_ignore_ascii_case("retry-after") && v == "1"),
            "429 must carry the Retry-After contract: {headers:?}"
        );
    }

    let (st, m) = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(st, 200);
    assert!(
        m.get_i64("rejected_429").unwrap() >= shed.len() as i64,
        "{m:?}"
    );
    handle.stop();
}
