//! The frozen runtime's allocation guarantees, enforced with a counting
//! `GlobalAlloc`:
//!
//! 1. **Warm sweeps allocate nothing.** Once the [`BatchScratch`] and the
//!    output vector are warm, `classify_batch_into` (round-based and
//!    cache-tiled, with and without step metering, scalar and
//!    kernel-pinned SIMD, plain and quantised/column-packed layouts)
//!    must not touch the allocator — the steady-state serving loop runs
//!    entirely on reused buffers. The tracing hot path (`ReqTrace` record/commit into the
//!    debug ring, per-shard timing atomics) runs inside the same counted
//!    window: with the inline breakdown off, observability costs zero
//!    allocations per request. The fault-tolerance plumbing rides in the
//!    same window: an *armed but never-firing* injection point and the
//!    per-request deadline load/compare must also cost zero allocations.
//! 2. **Snapshot boot is zero-copy.** `FrozenDD::load` on the mmap path
//!    must not copy or re-materialise node/terminal sections: total bytes
//!    allocated during the load stay far below the node-plane size (a
//!    single copied section would blow the bound), and the loaded model
//!    reports `mapped()`.
//! 3. **Bundle boot is zero-copy for every member.** A `fab-v1` bundle
//!    packed from ≥ 2 distinct models boots through **one** mapping:
//!    `Bundle::load` plus booting *all* entries stays under the same
//!    allocation bound relative to the combined node-plane bytes, every
//!    booted model reports `mapped()`, and answers are bit-identical to
//!    the pre-pack diagrams.
//!
//! This file deliberately holds a single `#[test]` so no concurrent test
//! thread can allocate inside the measurement windows.

use forest_add::compile::{CompileOptions, ForestCompiler};
use forest_add::data::datasets;
use forest_add::forest::ForestLearner;
use forest_add::frozen::bundle::{self, Bundle, BundleEntrySpec};
use forest_add::frozen::{BatchScratch, FrozenDD};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn alloc_bytes() -> u64 {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

#[test]
fn warm_sweeps_and_snapshot_boot_do_not_allocate() {
    let data = datasets::load("iris").unwrap();
    let forest = ForestLearner::default().trees(30).seed(5).fit(&data);
    let dd = ForestCompiler::new(CompileOptions::default())
        .compile(&forest)
        .unwrap();
    let frozen = dd.freeze();

    // Tile the dataset far past the batch-vs-walk crossover so the
    // sweeps (not the per-row fallback) run.
    let tiled = forest_add::bench_support::tile_rows(&data, 2048, 7);
    let rows = tiled.as_matrix();

    let mut scratch = BatchScratch::new();
    let mut out = Vec::new();
    let mut steps = Vec::new();
    // Warm-up: sizes the scratch node/slot/chain arrays and the outputs,
    // for every sweep strategy the measurement loop exercises.
    frozen.classify_batch_into(rows, &mut scratch, &mut out);
    let want = out.clone();
    frozen.classify_batch_into_tiled(rows, &mut scratch, &mut out, 1);
    frozen.classify_batch_steps_into_tiled(rows, &mut scratch, &mut out, &mut steps, 1);
    let want_steps = steps.clone();
    // The quantised + column-packed freeze shares the scratch; warming it
    // sizes `scratch.packed` (the copy-permute buffer) and pins the
    // SIMD-kernel OnceLocks (env read + CPU probe allocate on first use).
    let opt = dd
        .freeze_with(forest_add::frozen::FreezeOpts {
            pack_features: true,
            quantize_f16: true,
        })
        .unwrap();
    let kernel = forest_add::runtime::simd::kernel();
    frozen.classify_batch_kernel_into(rows, &mut scratch, &mut out, 0, kernel);
    opt.classify_batch_into(rows, &mut scratch, &mut out);
    opt.classify_batch_into_tiled(rows, &mut scratch, &mut out, 1);
    // Warm the trace-id generator (seeds a OnceLock on first use).
    let _ = forest_add::obs::trace::next_id();
    // Arm an injection point at rate 0: the armed-but-silent draw path is
    // exactly what a production replica pays while a chaos spec targets a
    // different point. (This test binary holds a single #[test], so the
    // process-global fault tables are ours alone.)
    forest_add::runtime::fault::arm("eval_slow:0:9").unwrap();

    let before = allocs();
    for _ in 0..10 {
        // The per-request trace hot path brackets every sweep exactly as
        // the serving loop does: stage records, shard-timing atomics and
        // the seqlock ring commit must all stay allocation-free.
        let mut trace =
            forest_add::obs::trace::ReqTrace::new(forest_add::obs::trace::next_id());
        trace.record(forest_add::obs::trace::Stage::Parse);
        // Deadline stamping + the expiry compare the serving loop runs
        // around every eval, and the armed-at-rate-0 fault draw the
        // guarded sweeps run per shard.
        trace.set_deadline(std::time::Instant::now() + std::time::Duration::from_secs(60));
        forest_add::obs::trace::set_eval_deadline(trace.deadline());
        let d = forest_add::obs::trace::eval_deadline();
        assert!(!d.is_some_and(|d| std::time::Instant::now() >= d));
        assert!(!forest_add::runtime::fault::fires(
            forest_add::runtime::fault::Point::EvalSlow
        ));
        assert!(!forest_add::runtime::fault::fires(
            forest_add::runtime::fault::Point::EvalShardPanic
        ));
        // round-based counting scatter (diagram fits the default budget)
        frozen.classify_batch_into(rows, &mut scratch, &mut out);
        assert_eq!(out, want, "warm sweeps must stay bit-identical");
        // cache-tiled chain sweep (budget 1 forces minimum-size tiles)
        frozen.classify_batch_into_tiled(rows, &mut scratch, &mut out, 1);
        assert_eq!(out, want, "warm tiled sweeps must stay bit-identical");
        // steps-metered tiled sweep
        frozen.classify_batch_steps_into_tiled(rows, &mut scratch, &mut out, &mut steps, 1);
        assert_eq!(out, want);
        assert_eq!(steps, want_steps, "warm metered sweeps must stay bit-identical");
        // kernel-pinned sweep (whatever kernel this host detects)
        frozen.classify_batch_kernel_into(rows, &mut scratch, &mut out, 0, kernel);
        assert_eq!(out, want, "warm SIMD sweeps must stay bit-identical");
        // quantised + column-packed layout: the per-batch copy-permute
        // into the warm scratch.packed buffer must not allocate either
        opt.classify_batch_into(rows, &mut scratch, &mut out);
        assert_eq!(out, want, "warm quantised sweeps must stay bit-identical");
        opt.classify_batch_into_tiled(rows, &mut scratch, &mut out, 1);
        assert_eq!(out, want, "warm quantised tiled sweeps must stay bit-identical");
        trace.record(forest_add::obs::trace::Stage::Eval);
        forest_add::obs::trace::record_shard(0, 7);
        forest_add::obs::trace::note_shard_run(1);
        trace.record(forest_add::obs::trace::Stage::Serialize);
        forest_add::obs::trace::set_eval_deadline(None);
        let total = trace.commit(200);
        assert!(trace.stages_total_us() <= total);
    }
    let after = allocs();
    forest_add::runtime::fault::disarm_all();
    assert_eq!(
        after - before,
        0,
        "the warm frozen sweeps plus the tracing hot path must not allocate \
         ({} allocations in 60 batches)",
        after - before
    );

    // ---- snapshot boot: the mmap path must not copy node/terminal
    // sections. Use a diagram big enough that copying even a single
    // plane would blow the allocation bound. ----
    let big_data = datasets::load("tic-tac-toe").unwrap();
    let big_forest = ForestLearner::default().trees(16).seed(11).fit(&big_data);
    let big_frozen = ForestCompiler::new(CompileOptions::default())
        .compile(&big_forest)
        .unwrap()
        .freeze();
    let path = std::env::temp_dir().join(format!("alloc-frozen-{}.fdd", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    big_frozen.save(&path_s).unwrap();
    let file_len = std::fs::metadata(&path).unwrap().len();
    let summary = forest_add::frozen::snapshot::summarize(&std::fs::read(&path).unwrap()).unwrap();
    let node_bytes = summary.node_section_bytes() as u64;
    assert!(
        node_bytes > 2048,
        "the fixture diagram must be big enough to make a copied section visible \
         ({node_bytes} node bytes)"
    );

    let before_bytes = alloc_bytes();
    let loaded = FrozenDD::load(&path_s).unwrap();
    let loaded_bytes = alloc_bytes() - before_bytes;
    if forest_add::runtime::mmap::enabled() {
        assert!(loaded.mapped(), "unix 64-bit loads must take the mmap path");
        // Validation scratch (reachability bitmaps, ~1 byte/node), the
        // schema strings and the section table allocate a little;
        // copying even the smallest node plane (4 bytes/node of the
        // 18 node-section bytes) would break this bound.
        assert!(
            loaded_bytes < node_bytes / 4,
            "mmap load allocated {loaded_bytes} bytes against {node_bytes} node-section bytes \
             (file {file_len} bytes) — a node/terminal section was copied"
        );
    } else {
        assert!(!loaded.mapped());
    }
    // the zero-copy model serves the same answers as the in-memory one
    for i in (0..big_data.n_rows()).step_by(37) {
        assert_eq!(
            loaded.classify_with_steps(big_data.row(i)),
            big_frozen.classify_with_steps(big_data.row(i)),
            "row {i}"
        );
    }
    drop(loaded);
    let _ = std::fs::remove_file(&path);

    // ---- bundle boot: two distinct models, one mapping, zero copies of
    // any member's node/terminal sections. ----
    let fab_path = std::env::temp_dir().join(format!("alloc-fab-{}.fab", std::process::id()));
    let fab_path_s = fab_path.to_str().unwrap().to_string();
    let fab_bytes = bundle::pack(&[
        BundleEntrySpec {
            name: "iris".into(),
            version: 1,
            shard: "shard-0".into(),
            dd: &frozen,
        },
        BundleEntrySpec {
            name: "tic-tac-toe".into(),
            version: 1,
            shard: "shard-1".into(),
            dd: &big_frozen,
        },
    ])
    .unwrap();
    bundle::save(&fab_path_s, &fab_bytes).unwrap();
    let iris_node_bytes = forest_add::frozen::snapshot::summarize(&frozen.to_bytes())
        .unwrap()
        .node_section_bytes() as u64;
    let total_node_bytes = node_bytes + iris_node_bytes;

    let before_bytes = alloc_bytes();
    let booted_bundle = Bundle::load(&fab_path_s).unwrap();
    let m_iris = booted_bundle.boot(0).unwrap();
    let m_ttt = booted_bundle.boot(1).unwrap();
    let bundle_alloc = alloc_bytes() - before_bytes;
    if forest_add::runtime::mmap::enabled() {
        assert!(booted_bundle.mapped(), "bundle loads must take the mmap path");
        assert!(m_iris.mapped(), "entry 0 must borrow the shared mapping");
        assert!(m_ttt.mapped(), "entry 1 must borrow the shared mapping");
        // Same bound as the single snapshot, over the combined planes:
        // manifest strings + two validations allocate a little, copying
        // any member's smallest node plane would break it.
        assert!(
            bundle_alloc < total_node_bytes / 4,
            "bundle boot allocated {bundle_alloc} bytes against {total_node_bytes} combined \
             node-section bytes — a member's node/terminal section was copied"
        );
    } else {
        assert!(!booted_bundle.mapped());
    }
    // both members answer bit-identically to their pre-pack diagrams
    for i in (0..data.n_rows()).step_by(13) {
        assert_eq!(
            m_iris.classify_with_steps(data.row(i)),
            frozen.classify_with_steps(data.row(i)),
            "iris row {i}"
        );
    }
    for i in (0..big_data.n_rows()).step_by(37) {
        assert_eq!(
            m_ttt.classify_with_steps(big_data.row(i)),
            big_frozen.classify_with_steps(big_data.row(i)),
            "tic-tac-toe row {i}"
        );
    }
    drop((m_iris, m_ttt, booted_bundle));
    let _ = std::fs::remove_file(&fab_path);
}
