//! The frozen sweep's zero-allocation guarantee, enforced with a
//! counting `GlobalAlloc`: once the [`BatchScratch`] and the output
//! vector are warm, `classify_batch_into` must not touch the allocator —
//! the steady-state serving loop runs entirely on reused buffers.
//!
//! This file deliberately holds a single `#[test]` so no concurrent test
//! thread can allocate inside the measurement window.

use forest_add::compile::{CompileOptions, ForestCompiler};
use forest_add::data::datasets;
use forest_add::forest::ForestLearner;
use forest_add::frozen::BatchScratch;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn warm_frozen_sweep_allocates_nothing() {
    let data = datasets::load("iris").unwrap();
    let forest = ForestLearner::default().trees(30).seed(5).fit(&data);
    let dd = ForestCompiler::new(CompileOptions::default())
        .compile(&forest)
        .unwrap();
    let frozen = dd.freeze();

    // Tile the dataset far past the batch-vs-walk crossover so the
    // counting-scatter sweep (not the per-row fallback) runs.
    let tiled = forest_add::bench_support::tile_rows(&data, 2048, 7);
    let rows = tiled.as_matrix();

    let mut scratch = BatchScratch::new();
    let mut out = Vec::new();
    // Warm-up: sizes the scratch node/slot arrays and the output vector.
    frozen.classify_batch_into(rows, &mut scratch, &mut out);
    let want = out.clone();

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..10 {
        frozen.classify_batch_into(rows, &mut scratch, &mut out);
        assert_eq!(out, want, "warm sweeps must stay bit-identical");
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "the warm frozen sweep must not allocate ({} allocations in 10 batches)",
        after - before
    );
}
