//! Integration: the observability surface over real sockets — request-id
//! propagation on both front-ends, inline `"trace": true` breakdowns
//! against the `/debug/trace` ring, and the Prometheus text exposition
//! agreeing with the JSON `/metrics` snapshot under live traffic.

use forest_add::data::datasets;
use forest_add::serve::config::{IoMode, ServeConfig};
use forest_add::serve::http::HttpClient;
use forest_add::serve::server;
use forest_add::util::json::{self, Json};

fn test_config() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        dataset: "iris".into(),
        trees: 32,
        max_depth: 6,
        seed: 7,
        enable_xla: false,
        ..Default::default()
    }
}

fn row_json(row: &[f32]) -> Json {
    Json::Arr(row.iter().map(|&v| json::num(v as f64)).collect())
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case(name))
        .map(|(_, v)| v.as_str())
}

/// The exact-name sample value from a Prometheus text scrape (skips
/// `_bucket{le=...}` lines and `# HELP`/`# TYPE` comments).
fn prom_sample(text: &str, name: &str) -> f64 {
    for l in text.lines() {
        if let Some(rest) = l.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ') {
                return v.trim().parse().unwrap();
            }
        }
    }
    panic!("series {name} absent from scrape");
}

/// The `(le, cumulative count)` bucket series of a histogram, in file
/// order (ascending `le`, ending at `+Inf`).
fn prom_buckets(text: &str, name: &str) -> Vec<(String, f64)> {
    let prefix = format!("{name}_bucket{{le=\"");
    text.lines()
        .filter_map(|l| l.strip_prefix(prefix.as_str()))
        .map(|rest| {
            let (le, v) = rest.split_once("\"}").unwrap();
            (le.to_string(), v.trim().parse().unwrap())
        })
        .collect()
}

/// Every response carries `X-Request-Id` on both front-ends: a
/// client-supplied id echoes verbatim, an absent one is generated as a
/// 16-hex-digit id. `/healthz` reports liveness plus the model count.
#[test]
fn request_id_echo_and_healthz_on_both_front_ends() {
    let mut configs = vec![ServeConfig {
        io_mode: IoMode::Sync,
        ..test_config()
    }];
    if forest_add::net::poll::supported() {
        configs.push(ServeConfig {
            io_mode: IoMode::Evented,
            ..test_config()
        });
    }
    let data = datasets::load("iris").unwrap();
    for cfg in configs {
        let mode = format!("{:?}", cfg.io_mode);
        let handle = server::start(&cfg).unwrap();
        let addr = handle.addr.to_string();
        let mut client = HttpClient::connect(&addr).unwrap();
        let body = json::obj(vec![("features", row_json(data.row(0)))])
            .to_string_compact()
            .into_bytes();

        let (st, headers, _) = client
            .request_raw_with_headers(
                "POST",
                "/classify",
                "application/json",
                &[("X-Request-Id", "trace-me-42")],
                &body,
            )
            .unwrap();
        assert_eq!(st, 200, "{mode}");
        assert_eq!(
            header(&headers, "x-request-id"),
            Some("trace-me-42"),
            "{mode}: client id must echo verbatim: {headers:?}"
        );

        let (st, headers, _) = client
            .request_raw("POST", "/classify", "application/json", &body)
            .unwrap();
        assert_eq!(st, 200, "{mode}");
        let id = header(&headers, "x-request-id")
            .unwrap_or_else(|| panic!("{mode}: generated id missing: {headers:?}"));
        assert_eq!(id.len(), 16, "{mode}: {id:?}");
        assert!(id.bytes().all(|b| b.is_ascii_hexdigit()), "{mode}: {id:?}");

        let (st, h) = client.get("/healthz").unwrap();
        assert_eq!(st, 200, "{mode}");
        assert_eq!(h.get("ok").and_then(|v| v.as_bool()), Some(true), "{mode}");
        assert!(h.get_i64("models").unwrap() >= 1, "{mode}: {h:?}");
        handle.stop();
    }
}

/// The inline `"trace": true` breakdown: stage spans are sequential
/// slices of the measured total (their sum can never exceed the largest
/// `request_us` observation), and the committed trace is retrievable
/// from the `/debug/trace` ring by its id.
#[test]
fn inline_trace_breakdown_and_debug_ring() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let data = datasets::load("iris").unwrap();
    let mut client = HttpClient::connect(&addr).unwrap();

    // no trace requested -> the body stays trace-free (bit-identity)
    let plain = json::obj(vec![("features", row_json(data.row(0)))]);
    let (st, resp) = client.request_json("POST", "/classify", Some(&plain)).unwrap();
    assert_eq!(st, 200);
    assert!(resp.get("trace").is_none(), "{resp:?}");

    // 16 hex digits parse verbatim: header echo, inline id, and the
    // ring entry all agree on the same identifier
    let wire_id = "00000000c0ffee42";
    let body = json::obj(vec![
        ("features", row_json(data.row(1))),
        ("trace", Json::Bool(true)),
    ])
    .to_string_compact()
    .into_bytes();
    let (st, headers, raw) = client
        .request_raw_with_headers(
            "POST",
            "/classify",
            "application/json",
            &[("X-Request-Id", wire_id)],
            &body,
        )
        .unwrap();
    assert_eq!(st, 200);
    assert_eq!(header(&headers, "x-request-id"), Some(wire_id));
    let resp = Json::parse(std::str::from_utf8(&raw).unwrap()).unwrap();
    let trace = resp.get("trace").unwrap_or_else(|| panic!("{resp:?}"));
    assert_eq!(trace.get_str("id"), Some(wire_id));
    let stages = trace.get("stages").unwrap();
    let mut stage_sum = 0i64;
    for name in ["parse", "admission", "queue", "eval", "serialize", "write"] {
        stage_sum += stages
            .get_i64(name)
            .unwrap_or_else(|| panic!("stage {name} missing: {stages:?}"));
    }

    let (st, m) = client.get("/metrics").unwrap();
    assert_eq!(st, 200);
    let max_us = m.get("request_us").unwrap().get_i64("max_us").unwrap();
    assert!(
        stage_sum <= max_us,
        "stage sum {stage_sum} exceeds the largest observed request_us {max_us}"
    );

    let (st, dbg) = client.get("/debug/trace?n=256").unwrap();
    assert_eq!(st, 200);
    let traces = dbg.get("traces").unwrap().as_arr().unwrap();
    assert!(!traces.is_empty());
    let ours = traces
        .iter()
        .find(|t| t.get_str("id") == Some(wire_id))
        .unwrap_or_else(|| panic!("trace {wire_id} not in the ring"));
    assert_eq!(ours.get_i64("status"), Some(200));
    // the ring entry's total includes serialize + write, the inline
    // breakdown stops at eval — total bounds the inline sum too
    assert!(ours.get_i64("total_us").unwrap() >= stage_sum, "{ours:?}");
    assert!(ours.get("stages").unwrap().get_i64("eval").is_some());

    // a bounded request returns at most n entries
    let (st, dbg) = client.get("/debug/trace?n=2").unwrap();
    assert_eq!(st, 200);
    assert!(dbg.get("traces").unwrap().as_arr().unwrap().len() <= 2);
    handle.stop();
}

/// The Prometheus exposition under live traffic: required series
/// present, cumulative buckets monotone and ending at `_count`, and
/// `_count`/`_sum` agreeing exactly with the JSON snapshot for the
/// batcher histograms (which a metrics scrape cannot advance).
#[test]
fn prometheus_scrape_agrees_with_json_under_traffic() {
    let handle = server::start(&test_config()).unwrap();
    let addr = handle.addr.to_string();
    let data = datasets::load("iris").unwrap();
    const N: usize = 40;
    let mut client = HttpClient::connect(&addr).unwrap();
    for i in 0..N {
        // alternate singles and batches so the request and batcher
        // series all accumulate
        let (st, _) = if i % 2 == 0 {
            let body = json::obj(vec![("features", row_json(data.row(i % data.n_rows())))]);
            client.request_json("POST", "/classify", Some(&body)).unwrap()
        } else {
            let rows = Json::Arr(vec![
                row_json(data.row(i % data.n_rows())),
                row_json(data.row((i + 1) % data.n_rows())),
            ]);
            let body = json::obj(vec![("rows", rows)]);
            client
                .request_json("POST", "/classify_batch", Some(&body))
                .unwrap()
        };
        assert_eq!(st, 200, "request {i}");
    }

    let (st, _, prom_raw) = client
        .request_raw("GET", "/metrics?format=prometheus", "application/json", &[])
        .unwrap();
    assert_eq!(st, 200);
    let prom = String::from_utf8(prom_raw).unwrap();
    let (st, m) = client.get("/metrics").unwrap();
    assert_eq!(st, 200);

    // an unknown format is a clean 400, not a dead server
    let (st, _, _) = client
        .request_raw("GET", "/metrics?format=xml", "application/json", &[])
        .unwrap();
    assert_eq!(st, 400);

    assert!(prom_sample(&prom, "forest_request_us_count") >= N as f64);
    assert!(prom_sample(&prom, "forest_requests_total") >= N as f64);
    assert!(prom_sample(&prom, "forest_bytes_read_total") > 0.0);
    assert!(prom_sample(&prom, "forest_bytes_written_total") > 0.0);
    assert!(
        prom.contains("# TYPE forest_eval_shard_us summary"),
        "per-shard eval series header must always render"
    );

    let buckets = prom_buckets(&prom, "forest_request_us");
    assert!(!buckets.is_empty());
    let mut prev = 0.0;
    for (le, v) in &buckets {
        assert!(*v >= prev, "bucket le={le} decreased: {v} < {prev}");
        prev = *v;
    }
    assert_eq!(buckets.last().unwrap().0, "+Inf");
    assert_eq!(prev, prom_sample(&prom, "forest_request_us_count"));

    // nothing between the two scrapes touches the batcher, so its
    // histograms must agree exactly across the formats
    for (prom_name, json_key, mean_key) in [
        ("forest_batch_eval_us", "batch_eval_us", "mean_us"),
        ("forest_batch_size", "batch_size", "mean"),
    ] {
        let j = m.get(json_key).unwrap();
        let count = j.get_i64("count").unwrap() as f64;
        assert!(count > 0.0, "{json_key}: batch traffic must have landed");
        assert_eq!(prom_sample(&prom, &format!("{prom_name}_count")), count);
        let sum = prom_sample(&prom, &format!("{prom_name}_sum"));
        let want = j.get(mean_key).unwrap().as_f64().unwrap() * count;
        assert!(
            (sum - want).abs() <= 1.0,
            "{prom_name}: sum {sum} vs mean*count {want}"
        );
    }
    handle.stop();
}
