//! Property-based invariants over the whole stack, via the in-tree
//! `util::prop` engine (proptest is unavailable offline).
//!
//! Replay a failing case with `FOREST_ADD_PROP_SEED=<seed> cargo test`.

use forest_add::add::reduce::{enumerate_paths, reduce_feasible};
use forest_add::add::{ClassVector, Manager};
use forest_add::compile::{Abstraction, CompileOptions, ForestCompiler};
use forest_add::data::synth::{blobs, BlobSpec};
use forest_add::feas::dpll::conjunction_sat;
use forest_add::feas::conjunction_feasible;
use forest_add::forest::ForestLearner;
use forest_add::predicate::{Domain, Predicate, PredicateOrder, PredicatePool};
use forest_add::prop_assert;
use forest_add::util::json::Json;
use forest_add::util::prop::{check, Config, Gen};
use forest_add::util::rng::Rng;
use std::sync::Arc;

fn cfg(cases: usize) -> Config {
    Config {
        cases,
        ..Config::default()
    }
}

/// Core semantics-preservation property over random datasets and forests:
/// every abstraction (±unsat) answers exactly like the forest.
#[test]
fn prop_compiled_dd_agrees_with_forest() {
    check("dd agrees with forest", cfg(12), |g: &mut Gen| {
        let spec = BlobSpec {
            rows: g.usize(20, 60),
            features: g.usize(2, 4),
            classes: g.usize(2, 4),
            separation: g.f64(1.0, 4.0),
            noise: 1.0,
            seed: g.int(0, 1 << 30) as u64,
        };
        let data = blobs(&spec).map_err(|e| e.to_string())?;
        let forest = ForestLearner::default()
            .trees(g.usize(1, 12))
            .max_depth(g.usize(2, 5))
            .seed(g.int(0, 1 << 30) as u64)
            .fit(&data);
        let abstraction = *g.pick(&[Abstraction::Word, Abstraction::Vector, Abstraction::Majority]);
        let unsat = g.int(0, 1) == 1;
        let dd = ForestCompiler::new(CompileOptions {
            abstraction,
            unsat_elim: unsat,
            node_budget: 500_000,
            ..Default::default()
        })
        .compile(&forest)
        .map_err(|e| e.to_string())?;
        for i in 0..data.n_rows() {
            let x = data.row(i);
            prop_assert!(
                dd.classify(x) == forest.predict(x),
                "disagreement at row {i} ({abstraction:?}, unsat={unsat})"
            );
        }
        Ok(())
    });
}

/// The interval oracle and the DPLL(T) solver decide identically on random
/// conjunctions of threshold literals over mixed real/grid domains.
#[test]
fn prop_interval_equals_dpll() {
    check("interval == dpll", cfg(200), |g: &mut Gen| {
        let n_features = g.usize(1, 3);
        let domains: Vec<Domain> = (0..n_features)
            .map(|_| {
                if g.int(0, 1) == 1 {
                    Domain::Grid {
                        cardinality: g.usize(2, 5) as u32,
                    }
                } else {
                    Domain::Real
                }
            })
            .collect();
        let n_lits = g.usize(1, 8);
        let lits: Vec<(Predicate, bool)> = (0..n_lits)
            .map(|_| {
                (
                    Predicate {
                        feature: g.usize(0, n_features - 1) as u32,
                        threshold: (g.int(-6, 12) as f32) / 2.0,
                    },
                    g.int(0, 1) == 1,
                )
            })
            .collect();
        prop_assert!(
            conjunction_feasible(&domains, &lits) == conjunction_sat(&domains, &lits),
            "oracles disagree on {lits:?} over {domains:?}"
        );
        Ok(())
    });
}

/// After reduction, no path of the diagram is unsatisfiable, and the
/// reduced diagram matches the original on random feasible inputs.
#[test]
fn prop_reduce_sound_and_complete() {
    check("reduce sound+complete", cfg(40), |g: &mut Gen| {
        // random pool over 2 features
        let mut thresholds: Vec<f32> = (0..g.usize(2, 5))
            .map(|_| g.int(-4, 8) as f32 / 2.0)
            .collect();
        thresholds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        thresholds.dedup();
        let mut preds: Vec<Predicate> = Vec::new();
        for (f, _) in [(0u32, ()), (1u32, ())] {
            for &t in &thresholds {
                preds.push(Predicate {
                    feature: f,
                    threshold: t + f as f32 * 0.25,
                });
            }
        }
        let pool = Arc::new(PredicatePool::from_predicates(
            preds.clone(),
            vec![Domain::Real, Domain::Real],
            2,
        ));
        // random diagram built bottom-up
        let mut mgr: Manager<ClassVector> = Manager::new(pool.clone());
        let mut rng = Rng::new(g.int(0, 1 << 30) as u64);
        let mut layer: Vec<_> = (0..4)
            .map(|i| mgr.terminal(ClassVector::unit(i % 3, 3)))
            .collect();
        for level in (0..preds.len() as u32).rev() {
            if rng.chance(0.7) {
                let a = layer[rng.below_usize(layer.len())];
                let b = layer[rng.below_usize(layer.len())];
                let n = mgr.mk(level, a, b);
                layer.push(n);
            }
        }
        let root = *layer.last().unwrap();
        let reduced = reduce_feasible(&mut mgr, root);
        // soundness: identical results on random inputs
        for _ in 0..30 {
            let x = [rng.range_f64(-4.0, 6.0) as f32, rng.range_f64(-4.0, 6.0) as f32];
            prop_assert!(
                mgr.eval(root, &x).0 == mgr.eval(reduced, &x).0,
                "reduction changed semantics at {x:?}"
            );
        }
        // completeness: every surviving path satisfiable
        for path in enumerate_paths(&mgr, reduced, 500) {
            let lits: Vec<(Predicate, bool)> =
                path.iter().map(|&(l, v)| (pool.pred(l), v)).collect();
            prop_assert!(
                conjunction_sat(pool.domains(), &lits),
                "unsat path survived: {lits:?}"
            );
        }
        Ok(())
    });
}

/// Vote-count conservation: at any aggregation checkpoint, the vector DD's
/// terminal at any input sums to the number of aggregated trees.
#[test]
fn prop_vector_dd_vote_conservation() {
    check("vote conservation", cfg(15), |g: &mut Gen| {
        let data = blobs(&BlobSpec {
            rows: 40,
            seed: g.int(0, 1 << 30) as u64,
            ..Default::default()
        })
        .map_err(|e| e.to_string())?;
        let n = g.usize(2, 10);
        let forest = ForestLearner::default()
            .trees(n)
            .max_depth(4)
            .seed(1)
            .fit(&data);
        let dd = ForestCompiler::new(CompileOptions {
            abstraction: Abstraction::Vector,
            unsat_elim: g.int(0, 1) == 1,
            node_budget: 500_000,
            ..Default::default()
        })
        .compile(&forest)
        .map_err(|e| e.to_string())?;
        // classify_with_steps goes through the vector terminal; votes are
        // checked indirectly via agreement + steps >= |C|
        for i in 0..data.n_rows() {
            let (_, steps) = dd.classify_with_steps(data.row(i));
            prop_assert!(steps >= data.n_classes(), "missing |C| aggregation reads");
        }
        Ok(())
    });
}

/// JSON parser/printer round-trip on randomly generated documents.
#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.int(0, 3) } else { g.int(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.int(0, 1) == 1),
            2 => Json::Num((g.int(-1_000_000, 1_000_000) as f64) / 64.0),
            3 => Json::Str(
                (0..g.usize(0, 8))
                    .map(|_| *g.pick(&['a', 'ß', '"', '\\', '\n', '😀', ' ', '{']))
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize(0, 4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", cfg(300), |g: &mut Gen| {
        let v = random_json(g, 3);
        let compact = v.to_string_compact();
        let back = Json::parse(&compact).map_err(|e| format!("{e} on {compact}"))?;
        prop_assert!(back == v, "compact roundtrip failed: {compact}");
        let pretty = v.to_string_pretty();
        let back = Json::parse(&pretty).map_err(|e| format!("{e} on {pretty}"))?;
        prop_assert!(back == v, "pretty roundtrip failed");
        Ok(())
    });
}

/// Forest JSON persistence round-trips exactly (predictions identical).
#[test]
fn prop_forest_persistence_roundtrip() {
    check("forest persistence", cfg(10), |g: &mut Gen| {
        let data = blobs(&BlobSpec {
            rows: 30,
            features: 3,
            classes: 3,
            seed: g.int(0, 1 << 30) as u64,
            ..Default::default()
        })
        .map_err(|e| e.to_string())?;
        let forest = ForestLearner::default()
            .trees(g.usize(1, 8))
            .seed(g.int(0, 1 << 30) as u64)
            .fit(&data);
        let text = forest.to_json().to_string_compact();
        let back = forest_add::forest::RandomForest::from_json(
            &Json::parse(&text).map_err(|e| e.to_string())?,
        )
        .map_err(|e| e.to_string())?;
        for i in 0..data.n_rows() {
            prop_assert!(
                forest.predict(data.row(i)) == back.predict(data.row(i)),
                "prediction changed after roundtrip (row {i})"
            );
        }
        Ok(())
    });
}

/// The predicate-order ablation never changes semantics, only structure.
#[test]
fn prop_order_invariant_semantics() {
    check("order-invariant semantics", cfg(10), |g: &mut Gen| {
        let data = blobs(&BlobSpec {
            rows: 40,
            seed: g.int(0, 1 << 30) as u64,
            ..Default::default()
        })
        .map_err(|e| e.to_string())?;
        let forest = ForestLearner::default()
            .trees(6)
            .max_depth(4)
            .seed(2)
            .fit(&data);
        let mk = |order| {
            ForestCompiler::new(CompileOptions {
                order,
                node_budget: 500_000,
                ..Default::default()
            })
            .compile(&forest)
            .map_err(|e| e.to_string())
        };
        let a = mk(PredicateOrder::FeatureThreshold)?;
        let b = mk(PredicateOrder::FrequencyDesc)?;
        for i in 0..data.n_rows() {
            prop_assert!(
                a.classify(data.row(i)) == b.classify(data.row(i)),
                "orders disagree at row {i}"
            );
        }
        Ok(())
    });
}
