//! Integration: the `forest-add` binary end to end (spawned as a process).

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_forest-add"))
}

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("forest-add-it-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn no_args_prints_usage() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("serve"));
}

#[test]
fn datasets_lists_the_six_corpora() {
    let out = bin().arg("datasets").output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for name in [
        "iris",
        "balance-scale",
        "breast-cancer",
        "lenses",
        "tic-tac-toe",
        "vote",
    ] {
        assert!(stdout.contains(name), "missing {name} in:\n{stdout}");
    }
    assert!(stdout.contains("958"), "tic-tac-toe row count");
}

#[test]
fn train_compile_eval_workflow() {
    let dir = tmpdir("workflow");
    let model = dir.join("model.json");
    let out = bin()
        .args([
            "train",
            "--dataset",
            "lenses",
            "--trees",
            "12",
            "--out",
            model.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(model.exists());

    let dot = dir.join("dd.dot");
    let out = bin()
        .args([
            "compile",
            "--model",
            model.to_str().unwrap(),
            "--dot",
            dot.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Most frequent class DD*"));
    assert!(std::fs::read_to_string(&dot).unwrap().starts_with("digraph"));

    let out = bin()
        .args(["eval", "--dataset", "lenses", "--trees", "15"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("Random Forest"));
    assert!(stdout.contains("Most frequent class DD*"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn compile_word_and_vector_variants() {
    for (abstraction, expect) in [("word", "Class word DD*"), ("vector", "Class vector DD*")] {
        let out = bin()
            .args([
                "compile",
                "--dataset",
                "lenses",
                "--trees",
                "10",
                "--abstraction",
                abstraction,
            ])
            .output()
            .unwrap();
        assert!(out.status.success());
        assert!(String::from_utf8_lossy(&out.stdout).contains(expect));
    }
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("bogus").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn artifacts_command_lists_variants() {
    if !std::path::Path::new("artifacts/index.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = bin().args(["artifacts", "--dir", "artifacts"]).output().unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    for v in ["small", "base", "wide"] {
        assert!(stdout.contains(v), "{stdout}");
    }
}

#[test]
fn serve_dump_config() {
    let out = bin()
        .args(["serve", "--dataset", "vote", "--trees", "64", "--dump-config"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("\"dataset\": \"vote\""));
    assert!(stdout.contains("\"trees\": 64"));
}
