//! Fault-tolerance integration: the deterministic injection harness
//! drives shard panics, connection read errors, short writes and slow
//! shards through both serving front-ends over real sockets, and the
//! process must degrade — never die:
//!
//! 1. **Chaos soak.** 64 keep-alive connections sweep both front-ends
//!    while `eval_shard_panic` / `eval_slow` / `conn_read_err` /
//!    `conn_write_short` are armed. Every response that completes with
//!    `200` is bit-identical (latency and routing metadata aside) to the
//!    fault-free reference — degradation is a routing change, never a
//!    semantic one — and both servers stay healthy.
//! 2. **Breaker lifecycle, deterministically.** At panic rate 1.0 the
//!    frozen backend fails every eval: three failures trip its breaker
//!    (`/readyz` → `503` naming `default@v1/frozen`, `/metrics` reports
//!    `degraded`), requests transparently reroute to the bit-identical
//!    dd backend with `X-Served-By`, and after disarm + cooldown the
//!    half-open probe re-closes the breaker (`/readyz` → `200`).
//! 3. **Deadline propagation.** With a 25 ms stall injected, a 5 ms
//!    `X-Deadline-Ms` budget comes back `504` (and lands in
//!    `deadline_dropped`), a generous budget absorbs the stall.
//! 4. **Replay.** Re-arming the same `point:rate:seed` spec replays the
//!    exact same fire/no-fire sequence.
//!
//! The fault tables are process-global, so this file holds a single
//! `#[test]` (the parallel runner must not interleave another arming).

use forest_add::data::datasets;
use forest_add::runtime::fault::{self, Point};
use forest_add::serve::config::{IoMode, ServeConfig};
use forest_add::serve::http::{http_request, HttpClient};
use forest_add::serve::server;
use forest_add::util::json::{self, strip_key, Json};
use std::time::Duration;

const CONNS: usize = 64;
const REQUESTS: usize = 4;

fn test_config() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        dataset: "iris".into(),
        trees: 32,
        max_depth: 6,
        seed: 7,
        enable_xla: false,
        breaker_threshold: 3,
        breaker_cooldown_ms: 400,
        ..Default::default()
    }
}

fn row_json(row: &[f32]) -> Json {
    Json::Arr(row.iter().map(|&v| json::num(v as f64)).collect())
}

/// The deterministic request schedule: half the sweep targets the frozen
/// backend (where the eval injection points live), half the default.
fn soak_request(data: &forest_add::data::Dataset, conn: usize, seq: usize) -> (String, Vec<u8>) {
    let n = data.n_rows();
    let i = (conn * 31 + seq * 7) % n;
    let j = (i + 1) % n;
    let rows = || Json::Arr(vec![row_json(data.row(i)), row_json(data.row(j))]);
    let body = match seq % 4 {
        0 => json::obj(vec![
            ("features", row_json(data.row(i))),
            ("backend", json::s("frozen")),
        ]),
        1 => json::obj(vec![("features", row_json(data.row(i)))]),
        2 => json::obj(vec![
            ("rows", rows()),
            ("backend", json::s("frozen")),
            ("steps", Json::Bool(true)),
        ]),
        _ => json::obj(vec![("rows", rows())]),
    };
    let path = if seq % 4 < 2 {
        "/classify"
    } else {
        "/classify_batch"
    };
    (path.to_string(), body.to_string_compact().into_bytes())
}

/// Strip the fields a legitimate degradation is allowed to change:
/// latency, the serving backend, and the reroute marker.
fn sanitize(v: &Json) -> Json {
    strip_key(&strip_key(&strip_key(v, "latency_us"), "backend"), "served_by")
}

/// One request that survives injected connection drops: on a transport
/// error (or an error response, which hangs up) the connection is
/// re-established and the request retried.
fn resilient_request(
    addr: &str,
    client: &mut Option<HttpClient>,
    path: &str,
    body: &[u8],
) -> (u16, Vec<u8>) {
    for _ in 0..20 {
        if client.is_none() {
            match HttpClient::connect(addr) {
                Ok(c) => *client = Some(c),
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(5));
                    continue;
                }
            }
        }
        match client
            .as_mut()
            .unwrap()
            .request_raw("POST", path, "application/json", body)
        {
            Ok((status, _, resp)) => {
                if status >= 400 {
                    *client = None; // error responses hang up
                }
                return (status, resp);
            }
            Err(_) => *client = None, // injected read error dropped the conn
        }
    }
    panic!("request to {addr} {path} never completed in 20 attempts");
}

#[test]
fn injected_faults_degrade_but_never_kill_the_servers() {
    if !forest_add::net::poll::supported() {
        eprintln!("skipping: no epoll/kqueue on this target");
        return;
    }
    fault::disarm_all(); // a clean slate regardless of FOREST_ADD_FAULT
    let sync_handle = server::start(&ServeConfig {
        io_mode: IoMode::Sync,
        http_workers: CONNS + 8,
        ..test_config()
    })
    .unwrap();
    let evented_handle = server::start(&ServeConfig {
        io_mode: IoMode::Evented,
        http_workers: 8,
        ..test_config()
    })
    .unwrap();
    let sync_addr = sync_handle.addr.to_string();
    let evented_addr = evented_handle.addr.to_string();
    let data = datasets::load("iris").unwrap();

    // --- fault-free reference: both servers, every scheduled request ---
    for addr in [&sync_addr, &evented_addr] {
        let (st, r) = http_request(addr, "GET", "/readyz", None).unwrap();
        assert_eq!(st, 200, "fresh server must be ready: {r:?}");
    }
    let reference: Vec<Vec<Json>> = {
        let mut sync_client = HttpClient::connect(&sync_addr).unwrap();
        let mut evented_client = HttpClient::connect(&evented_addr).unwrap();
        (0..CONNS)
            .map(|c| {
                (0..REQUESTS)
                    .map(|r| {
                        let (path, body) = soak_request(&data, c, r);
                        let (st_s, _, b_s) = sync_client
                            .request_raw("POST", &path, "application/json", &body)
                            .unwrap();
                        let (st_e, _, b_e) = evented_client
                            .request_raw("POST", &path, "application/json", &body)
                            .unwrap();
                        assert_eq!(st_s, 200, "reference {c}/{r} (sync)");
                        assert_eq!(st_e, 200, "reference {c}/{r} (evented)");
                        let v_s = Json::parse(std::str::from_utf8(&b_s).unwrap()).unwrap();
                        let v_e = Json::parse(std::str::from_utf8(&b_e).unwrap()).unwrap();
                        let want = sanitize(&v_s);
                        assert_eq!(want, sanitize(&v_e), "reference {c}/{r} diverges");
                        want
                    })
                    .collect()
            })
            .collect()
    };

    // --- chaos soak: 64 connections per front-end under armed faults ---
    fault::arm(
        "eval_shard_panic:0.3:42,eval_slow:0.1:11,conn_read_err:0.05:7,conn_write_short:0.2:3",
    )
    .unwrap();
    std::thread::scope(|scope| {
        for c in 0..CONNS {
            let sync_addr = &sync_addr;
            let evented_addr = &evented_addr;
            let data = &data;
            let reference = &reference;
            scope.spawn(move || {
                let mut sync_client = None;
                let mut evented_client = None;
                for r in 0..REQUESTS {
                    let (path, body) = soak_request(data, c, r);
                    for (addr, client) in [
                        (sync_addr.as_str(), &mut sync_client),
                        (evented_addr.as_str(), &mut evented_client),
                    ] {
                        let (status, resp) = resilient_request(addr, client, &path, &body);
                        assert!(
                            matches!(status, 200 | 429 | 500 | 503 | 504),
                            "conn {c} req {r} {addr}: unexpected status {status}"
                        );
                        if status == 200 {
                            let v = Json::parse(std::str::from_utf8(&resp).unwrap()).unwrap();
                            assert_eq!(
                                sanitize(&v),
                                reference[c][r],
                                "conn {c} req {r} {addr}: a served answer diverged under faults"
                            );
                        }
                    }
                }
            });
        }
    });
    // both processes survived, counted their injections, and expose them
    for addr in [&sync_addr, &evented_addr] {
        let (st, _) = http_request(addr, "GET", "/healthz", None).unwrap();
        assert_eq!(st, 200, "{addr} must survive the soak");
        let (st, m) = http_request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(st, 200);
        let f = m.get("fault").unwrap();
        assert!(
            f.get_i64("injected").unwrap() > 0,
            "{addr}: no fault ever fired: {m:?}"
        );
        let mut c = HttpClient::connect(addr).unwrap();
        let (st, _, text) = c
            .request_raw("GET", "/metrics?format=prometheus", "text/plain", &[])
            .unwrap();
        assert_eq!(st, 200);
        let text = String::from_utf8(text).unwrap();
        assert!(text.contains("forest_eval_panics_total"), "{addr}");
        assert!(text.contains("forest_faults_injected_total"), "{addr}");
    }

    // --- quiesce: heal whatever state the chaos left behind ------------
    // The soak trips frozen breakers nondeterministically; before the
    // deterministic lifecycle phase below, let any open breaker reach
    // its cooldown and send one healthy frozen eval per server — the
    // success re-closes a tripped breaker and clears the residual
    // failure window, so the next phase starts from a clean slate.
    let frozen_body = json::obj(vec![
        ("features", row_json(data.row(0))),
        ("backend", json::s("frozen")),
    ])
    .to_string_compact()
    .into_bytes();
    fault::disarm_all();
    std::thread::sleep(Duration::from_millis(600)); // > breaker_cooldown_ms
    for addr in [&sync_addr, &evented_addr] {
        let mut client = HttpClient::connect(addr).unwrap();
        let (st, _, _) = client
            .request_raw("POST", "/classify", "application/json", &frozen_body)
            .unwrap();
        assert_eq!(st, 200, "{addr}: quiesce probe");
        let (st, r) = http_request(addr, "GET", "/readyz", None).unwrap();
        assert_eq!(st, 200, "{addr}: quiesced server must be ready: {r:?}");
    }

    // --- breaker lifecycle, deterministically: rate 1.0 panics ---------
    fault::arm("eval_shard_panic:1:99").unwrap();
    for addr in [&sync_addr, &evented_addr] {
        let panics_before = {
            let (_, m) = http_request(addr, "GET", "/metrics", None).unwrap();
            m.get("fault").unwrap().get_i64("eval_panics").unwrap()
        };
        for k in 0..4 {
            let mut client = HttpClient::connect(addr).unwrap();
            let (st, headers, body) = client
                .request_raw("POST", "/classify", "application/json", &frozen_body)
                .unwrap();
            assert_eq!(
                st,
                200,
                "{addr} req {k}: a shard panic must degrade, not fail: {}",
                String::from_utf8_lossy(&body)
            );
            assert!(
                headers
                    .iter()
                    .any(|(k2, v)| k2.eq_ignore_ascii_case("x-served-by") && v == "dd"),
                "{addr} req {k}: degraded response must announce its backend: {headers:?}"
            );
        }
        // three failures tripped the frozen breaker; the fourth request
        // was routed straight to dd without another panic
        let (st, r) = http_request(addr, "GET", "/readyz", None).unwrap();
        assert_eq!(st, 503, "{addr}: open breaker must fail readiness: {r:?}");
        assert!(
            r.to_string_compact().contains("default@v1/frozen"),
            "{addr}: readyz must name the open breaker: {r:?}"
        );
        let (_, m) = http_request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(m.get("degraded"), Some(&Json::Bool(true)), "{addr}: {m:?}");
        let b = m.get("breakers").unwrap();
        assert!(b.get_i64("open").unwrap() >= 1, "{addr}: {m:?}");
        assert!(b.get_i64("trips").unwrap() >= 1, "{addr}: {m:?}");
        let panics = m.get("fault").unwrap().get_i64("eval_panics").unwrap();
        assert_eq!(
            panics - panics_before,
            3,
            "{addr}: exactly the three pre-trip evals panic"
        );
    }

    // --- recovery: disarm, wait out the cooldown, probe re-closes ------
    fault::disarm_all();
    std::thread::sleep(Duration::from_millis(600)); // > breaker_cooldown_ms
    for addr in [&sync_addr, &evented_addr] {
        let mut client = HttpClient::connect(addr).unwrap();
        let (st, headers, _) = client
            .request_raw("POST", "/classify", "application/json", &frozen_body)
            .unwrap();
        assert_eq!(st, 200, "{addr}: half-open probe");
        assert!(
            !headers
                .iter()
                .any(|(k, _)| k.eq_ignore_ascii_case("x-served-by")),
            "{addr}: the successful probe must re-close and serve primary: {headers:?}"
        );
        let (st, r) = http_request(addr, "GET", "/readyz", None).unwrap();
        assert_eq!(st, 200, "{addr}: recovered server must be ready: {r:?}");
        let (_, m) = http_request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(m.get("degraded"), Some(&Json::Bool(false)), "{addr}: {m:?}");
        assert_eq!(
            m.get("breakers").unwrap().get_i64("open"),
            Some(0),
            "{addr}: {m:?}"
        );
    }

    // --- deadline propagation under an injected 25 ms stall ------------
    fault::arm("eval_slow:1:5").unwrap();
    for addr in [&sync_addr, &evented_addr] {
        let mut client = HttpClient::connect(addr).unwrap();
        let (st, _, body) = client
            .request_raw_with_headers(
                "POST",
                "/classify",
                "application/json",
                &[("X-Deadline-Ms", "5")],
                &frozen_body,
            )
            .unwrap();
        assert_eq!(
            st,
            504,
            "{addr}: a 5 ms budget cannot absorb the stall: {}",
            String::from_utf8_lossy(&body)
        );
        let mut client = HttpClient::connect(addr).unwrap();
        let (st, _, _) = client
            .request_raw_with_headers(
                "POST",
                "/classify",
                "application/json",
                &[("X-Deadline-Ms", "5000")],
                &frozen_body,
            )
            .unwrap();
        assert_eq!(st, 200, "{addr}: a generous budget absorbs the stall");
        let (_, m) = http_request(addr, "GET", "/metrics", None).unwrap();
        assert!(
            m.get("fault").unwrap().get_i64("deadline_dropped").unwrap() >= 1,
            "{addr}: {m:?}"
        );
    }
    fault::disarm_all();
    sync_handle.stop();
    evented_handle.stop();

    // --- replay: the same spec fires the same deterministic sequence ---
    fault::arm("conn_write_short:0.5:77").unwrap();
    let first: Vec<bool> = (0..64).map(|_| fault::fires(Point::ConnWriteShort)).collect();
    fault::arm("conn_write_short:0.5:77").unwrap();
    let second: Vec<bool> = (0..64).map(|_| fault::fires(Point::ConnWriteShort)).collect();
    assert_eq!(first, second, "same point:rate:seed must replay exactly");
    assert!(
        first.iter().any(|&b| b) && first.iter().any(|&b| !b),
        "rate 0.5 over 64 draws mixes fires and passes: {first:?}"
    );
    fault::disarm_all();
}
