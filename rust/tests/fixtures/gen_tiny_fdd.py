#!/usr/bin/env python3
"""Generate tests/fixtures/tiny-v1.fdd, tiny-v2.fdd and tiny-v1.fab, the
forward-compat tripwires.

This is an *independent* implementation of the `forest-add/fdd` binary
snapshot formats and of the `forest-add/fab-v1` multi-model bundle
format (the prose specification is docs/FORMAT.md at the repository
root; rust/src/frozen/snapshot.rs and rust/src/frozen/bundle.rs are
the authoritative readers/writers). The checked-in fixtures are loaded by
tests/snapshot_compat.rs; if the Rust reader or writer drifts from the
documented layouts, those tests — not a customer's serving fleet — are
what break.

The diagram encoded in the fdd fixtures (majority abstraction, 2
features, classes ["a", "b"]):

    x0 < 0.5 ? "a" : (x1 < 0.5 ? "b" : "a")

Node arrays (topological, root first):
    node 0: level 0 (x0 < 0.5), hi -> terminal 0 ("a"), lo -> node 1
    node 1: level 1 (x1 < 0.5), hi -> terminal 1 ("b"), lo -> terminal 0

v1 stores absolute child references in a 12-byte-per-node AoS-ish
section; v2 stores the narrow hot plane (u16 feat + f32 thresh, 6 bytes),
forward-delta lo/hi arrays, the precomputed terminal class/aggregation
tables, and 64-byte-aligned sections.

The fab fixture bundles two *distinct* models: entry "tiny" is exactly
the tiny-v2.fdd bytes above, entry "tiny-flip" is a second single-node
diagram over the same schema:

    x1 < 0.5 ? "b" : "a"

fab-v1 layout: 40-byte header (magic FADD.FAB, version, entry count,
payload length, whole-file FNV-1a-64, reserved) + manifest records
(name str, version u64, shard str, offset u64, len u64, per-entry
FNV-1a-64) + the member snapshots at 64-byte-aligned offsets.

Run from anywhere:  python3 rust/tests/fixtures/gen_tiny_fdd.py
"""

import os
import struct

TERM_BIT = 1 << 31
HEADER_LEN = 40
TABLE_ENTRY_LEN = 24


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def string(s: str) -> bytes:
    raw = s.encode("utf-8")
    return struct.pack("<I", len(raw)) + raw


def schema() -> bytes:
    out = string("a") + string("b")  # classes
    for name in ("x0", "x1"):  # numeric features
        out += string(name) + b"\x00"
    return out


def preds() -> bytes:
    out = struct.pack("<II", 0, 1)  # feature per level
    out += struct.pack("<ff", 0.5, 0.5)  # threshold per level
    return out


def assemble(version: int, align: int, sections) -> bytes:
    payload = bytearray(len(sections) * TABLE_ENTRY_LEN)
    table = []
    for sec_id, data in sections:
        while (HEADER_LEN + len(payload)) % align:
            payload.append(0)
        table.append((sec_id, HEADER_LEN + len(payload), len(data)))
        payload += data
    entry = b"".join(
        struct.pack("<IIQQ", sec_id, 0, offset, length)
        for sec_id, offset, length in table
    )
    payload[: len(entry)] = entry
    header = b"FADD.FDD" + struct.pack(
        "<IIQQQ", version, len(sections), len(payload), fnv1a64(bytes(payload)), 0
    )
    return header + bytes(payload)


# ------------------------------------------------------------------- v1


def meta_v1() -> bytes:
    return struct.pack(
        "<BBHIIIIIIII",
        2,  # abstraction: majority
        1,  # unsat_elim
        0,  # reserved
        3,  # n_trees
        2,  # n_features
        2,  # n_classes
        2,  # n_preds
        2,  # n_nodes
        2,  # n_terminals
        0,  # root = node 0
        0,  # reserved
    )


def nodes_v1() -> bytes:
    out = struct.pack("<II", 0, 1)  # level
    out += struct.pack("<II", 1, TERM_BIT)  # lo (absolute)
    out += struct.pack("<II", TERM_BIT, TERM_BIT | 1)  # hi (absolute)
    return out


def build_v1() -> bytes:
    sections = [
        (1, meta_v1()),
        (2, schema()),
        (3, preds()),
        (4, nodes_v1()),
        (5, struct.pack("<HH", 0, 1)),  # majority classes per terminal
    ]
    return assemble(1, 8, sections)


# ------------------------------------------------------------------- v2


def meta_v2() -> bytes:
    return struct.pack(
        "<BBBBIIIIIIII",
        2,  # abstraction: majority
        1,  # unsat_elim
        2,  # feat_width: u16
        0,  # reserved
        3,  # n_trees
        2,  # n_features
        2,  # n_classes
        2,  # n_preds
        2,  # n_nodes
        2,  # n_terminals
        0,  # root = node 0
        0,  # reserved
    )


def build_v2() -> bytes:
    hot = struct.pack("<Hf", 0, 0.5) + struct.pack("<Hf", 1, 0.5)
    lo = struct.pack("<II", 1, TERM_BIT)  # node 0 -> node 1 is delta 1
    hi = struct.pack("<II", TERM_BIT, TERM_BIT | 1)
    sections = [
        (1, meta_v2()),
        (2, schema()),
        (3, preds()),
        (4, struct.pack("<II", 0, 1)),  # levels
        (5, hot),
        (6, lo),
        (7, hi),
        (8, struct.pack("<HH", 0, 1)),  # term class
        (9, struct.pack("<II", 0, 0)),  # term aggregation reads
        (10, struct.pack("<HH", 0, 1)),  # majority payload
    ]
    return assemble(2, 64, sections)


def meta_v2_flip() -> bytes:
    return struct.pack(
        "<BBBBIIIIIIII",
        2,  # abstraction: majority
        1,  # unsat_elim
        2,  # feat_width: u16
        0,  # reserved
        1,  # n_trees
        2,  # n_features
        2,  # n_classes
        1,  # n_preds
        1,  # n_nodes
        2,  # n_terminals
        0,  # root = node 0
        0,  # reserved
    )


def build_v2_flip() -> bytes:
    """A second, distinct model for the bundle fixture:
    x1 < 0.5 ? "b" : "a" (one node, two terminals, same schema)."""
    sections = [
        (1, meta_v2_flip()),
        (2, schema()),
        (3, struct.pack("<I", 1) + struct.pack("<f", 0.5)),  # preds
        (4, struct.pack("<I", 0)),  # levels
        (5, struct.pack("<Hf", 1, 0.5)),  # hot
        (6, struct.pack("<I", TERM_BIT)),  # lo -> terminal 0 ("a")
        (7, struct.pack("<I", TERM_BIT | 1)),  # hi -> terminal 1 ("b")
        (8, struct.pack("<HH", 0, 1)),  # term class
        (9, struct.pack("<II", 0, 0)),  # term aggregation reads
        (10, struct.pack("<HH", 0, 1)),  # majority payload
    ]
    return assemble(2, 64, sections)


# ------------------------------------------------------------------- fab


def build_fab(entries) -> bytes:
    """entries = [(name, version, shard, snapshot_bytes)]; mirrors the
    Rust writer in rust/src/frozen/bundle.rs byte for byte."""
    manifest_len = sum(
        4 + len(name.encode()) + 8 + 4 + len(shard.encode()) + 8 + 8 + 8
        for name, _, shard, _ in entries
    )
    pos = HEADER_LEN + manifest_len
    offsets = []
    for _, _, _, data in entries:
        pos += (-pos) % 64
        offsets.append(pos)
        pos += len(data)
    payload = bytearray()
    for (name, version, shard, data), off in zip(entries, offsets):
        payload += string(name)
        payload += struct.pack("<Q", version)
        payload += string(shard)
        payload += struct.pack("<QQQ", off, len(data), fnv1a64(data))
    assert len(payload) == manifest_len
    for (_, _, _, data), off in zip(entries, offsets):
        while HEADER_LEN + len(payload) < off:
            payload.append(0)
        payload += data
    header = b"FADD.FAB" + struct.pack(
        "<IIQQQ", 1, len(entries), len(payload), fnv1a64(bytes(payload)), 0
    )
    return header + bytes(payload)


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    v2 = build_v2()
    fab = build_fab(
        [
            ("tiny", 1, "shard-0", v2),
            ("tiny-flip", 1, "shard-1", build_v2_flip()),
        ]
    )
    for name, data in (
        ("tiny-v1.fdd", build_v1()),
        ("tiny-v2.fdd", v2),
        ("tiny-v1.fab", fab),
    ):
        out = os.path.join(here, name)
        with open(out, "wb") as f:
            f.write(data)
        print(
            f"wrote {out}: {len(data)} bytes, "
            f"checksum {fnv1a64(data[HEADER_LEN:]):#018x}"
        )


if __name__ == "__main__":
    main()
