#!/usr/bin/env python3
"""Generate tests/fixtures/tiny-v1.fdd, the forward-compat tripwire.

This is an *independent* implementation of the `forest-add/fdd-v1` binary
snapshot format (see rust/src/frozen/snapshot.rs for the authoritative
spec). The checked-in fixture is loaded by tests/snapshot_compat.rs; if
the Rust reader or writer drifts from the documented layout, that test —
not a customer's serving fleet — is what breaks.

The diagram encoded here (majority abstraction, 2 features, classes
["a", "b"]):

    x0 < 0.5 ? "a" : (x1 < 0.5 ? "b" : "a")

Node arrays (topological, root first):
    node 0: level 0 (x0 < 0.5), hi -> terminal 0 ("a"), lo -> node 1
    node 1: level 1 (x1 < 0.5), hi -> terminal 1 ("b"), lo -> terminal 0

Run from anywhere:  python3 rust/tests/fixtures/gen_tiny_fdd.py
"""

import os
import struct

TERM_BIT = 1 << 31
HEADER_LEN = 40
TABLE_ENTRY_LEN = 24


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def string(s: str) -> bytes:
    raw = s.encode("utf-8")
    return struct.pack("<I", len(raw)) + raw


def meta() -> bytes:
    return struct.pack(
        "<BBHIIIIIIII",
        2,  # abstraction: majority
        1,  # unsat_elim
        0,  # reserved
        3,  # n_trees
        2,  # n_features
        2,  # n_classes
        2,  # n_preds
        2,  # n_nodes
        2,  # n_terminals
        0,  # root = node 0
        0,  # reserved
    )


def schema() -> bytes:
    out = string("a") + string("b")  # classes
    for name in ("x0", "x1"):  # numeric features
        out += string(name) + b"\x00"
    return out


def preds() -> bytes:
    out = struct.pack("<II", 0, 1)  # feature per level
    out += struct.pack("<ff", 0.5, 0.5)  # threshold per level
    return out


def nodes() -> bytes:
    out = struct.pack("<II", 0, 1)  # level
    out += struct.pack("<II", 1, TERM_BIT)  # lo
    out += struct.pack("<II", TERM_BIT, TERM_BIT | 1)  # hi
    return out


def terms() -> bytes:
    return struct.pack("<HH", 0, 1)  # majority classes per terminal


def build() -> bytes:
    sections = [
        (1, meta()),
        (2, schema()),
        (3, preds()),
        (4, nodes()),
        (5, terms()),
    ]
    payload = bytearray(len(sections) * TABLE_ENTRY_LEN)
    table = []
    for sec_id, data in sections:
        while (HEADER_LEN + len(payload)) % 8:
            payload.append(0)
        table.append((sec_id, HEADER_LEN + len(payload), len(data)))
        payload += data
    entry = b"".join(
        struct.pack("<IIQQ", sec_id, 0, offset, length)
        for sec_id, offset, length in table
    )
    payload[: len(entry)] = entry
    header = b"FADD.FDD" + struct.pack(
        "<IIQQQ", 1, len(sections), len(payload), fnv1a64(bytes(payload)), 0
    )
    return header + bytes(payload)


def main() -> None:
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "tiny-v1.fdd")
    data = build()
    with open(out, "wb") as f:
        f.write(data)
    print(f"wrote {out}: {len(data)} bytes, checksum {fnv1a64(data[HEADER_LEN:]):#018x}")


if __name__ == "__main__":
    main()
