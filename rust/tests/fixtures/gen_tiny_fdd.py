#!/usr/bin/env python3
"""Generate tests/fixtures/tiny-v1.fdd and tiny-v2.fdd, the
forward-compat tripwires.

This is an *independent* implementation of the `forest-add/fdd` binary
snapshot formats (see rust/src/frozen/snapshot.rs for the authoritative
spec). The checked-in fixtures are loaded by tests/snapshot_compat.rs; if
the Rust reader or writer drifts from the documented layouts, those tests
— not a customer's serving fleet — are what break.

The diagram encoded in both fixtures (majority abstraction, 2 features,
classes ["a", "b"]):

    x0 < 0.5 ? "a" : (x1 < 0.5 ? "b" : "a")

Node arrays (topological, root first):
    node 0: level 0 (x0 < 0.5), hi -> terminal 0 ("a"), lo -> node 1
    node 1: level 1 (x1 < 0.5), hi -> terminal 1 ("b"), lo -> terminal 0

v1 stores absolute child references in a 12-byte-per-node AoS-ish
section; v2 stores the narrow hot plane (u16 feat + f32 thresh, 6 bytes),
forward-delta lo/hi arrays, the precomputed terminal class/aggregation
tables, and 64-byte-aligned sections.

Run from anywhere:  python3 rust/tests/fixtures/gen_tiny_fdd.py
"""

import os
import struct

TERM_BIT = 1 << 31
HEADER_LEN = 40
TABLE_ENTRY_LEN = 24


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def string(s: str) -> bytes:
    raw = s.encode("utf-8")
    return struct.pack("<I", len(raw)) + raw


def schema() -> bytes:
    out = string("a") + string("b")  # classes
    for name in ("x0", "x1"):  # numeric features
        out += string(name) + b"\x00"
    return out


def preds() -> bytes:
    out = struct.pack("<II", 0, 1)  # feature per level
    out += struct.pack("<ff", 0.5, 0.5)  # threshold per level
    return out


def assemble(version: int, align: int, sections) -> bytes:
    payload = bytearray(len(sections) * TABLE_ENTRY_LEN)
    table = []
    for sec_id, data in sections:
        while (HEADER_LEN + len(payload)) % align:
            payload.append(0)
        table.append((sec_id, HEADER_LEN + len(payload), len(data)))
        payload += data
    entry = b"".join(
        struct.pack("<IIQQ", sec_id, 0, offset, length)
        for sec_id, offset, length in table
    )
    payload[: len(entry)] = entry
    header = b"FADD.FDD" + struct.pack(
        "<IIQQQ", version, len(sections), len(payload), fnv1a64(bytes(payload)), 0
    )
    return header + bytes(payload)


# ------------------------------------------------------------------- v1


def meta_v1() -> bytes:
    return struct.pack(
        "<BBHIIIIIIII",
        2,  # abstraction: majority
        1,  # unsat_elim
        0,  # reserved
        3,  # n_trees
        2,  # n_features
        2,  # n_classes
        2,  # n_preds
        2,  # n_nodes
        2,  # n_terminals
        0,  # root = node 0
        0,  # reserved
    )


def nodes_v1() -> bytes:
    out = struct.pack("<II", 0, 1)  # level
    out += struct.pack("<II", 1, TERM_BIT)  # lo (absolute)
    out += struct.pack("<II", TERM_BIT, TERM_BIT | 1)  # hi (absolute)
    return out


def build_v1() -> bytes:
    sections = [
        (1, meta_v1()),
        (2, schema()),
        (3, preds()),
        (4, nodes_v1()),
        (5, struct.pack("<HH", 0, 1)),  # majority classes per terminal
    ]
    return assemble(1, 8, sections)


# ------------------------------------------------------------------- v2


def meta_v2() -> bytes:
    return struct.pack(
        "<BBBBIIIIIIII",
        2,  # abstraction: majority
        1,  # unsat_elim
        2,  # feat_width: u16
        0,  # reserved
        3,  # n_trees
        2,  # n_features
        2,  # n_classes
        2,  # n_preds
        2,  # n_nodes
        2,  # n_terminals
        0,  # root = node 0
        0,  # reserved
    )


def build_v2() -> bytes:
    hot = struct.pack("<Hf", 0, 0.5) + struct.pack("<Hf", 1, 0.5)
    lo = struct.pack("<II", 1, TERM_BIT)  # node 0 -> node 1 is delta 1
    hi = struct.pack("<II", TERM_BIT, TERM_BIT | 1)
    sections = [
        (1, meta_v2()),
        (2, schema()),
        (3, preds()),
        (4, struct.pack("<II", 0, 1)),  # levels
        (5, hot),
        (6, lo),
        (7, hi),
        (8, struct.pack("<HH", 0, 1)),  # term class
        (9, struct.pack("<II", 0, 0)),  # term aggregation reads
        (10, struct.pack("<HH", 0, 1)),  # majority payload
    ]
    return assemble(2, 64, sections)


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    for name, data in (("tiny-v1.fdd", build_v1()), ("tiny-v2.fdd", build_v2())):
        out = os.path.join(here, name)
        with open(out, "wb") as f:
            f.write(data)
        print(
            f"wrote {out}: {len(data)} bytes, "
            f"checksum {fnv1a64(data[HEADER_LEN:]):#018x}"
        )


if __name__ == "__main__":
    main()
