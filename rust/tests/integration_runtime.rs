//! Integration: the AOT bridge — Rust loads the JAX/Pallas-lowered HLO
//! artifacts via PJRT and the numerics match the native forest bit-for-bit.
//!
//! Requires `make artifacts` (skips with a notice otherwise, but the
//! Makefile `test` target always builds artifacts first).

use forest_add::batch::RowMatrixBuf;
use forest_add::data::datasets;
use forest_add::forest::ForestLearner;
use forest_add::runtime::{PackedForest, VariantMeta, XlaEngine};

fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/index.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping runtime integration: run `make artifacts` first");
        None
    }
}

#[test]
fn index_lists_all_variants() {
    let Some(dir) = artifacts() else { return };
    let names = VariantMeta::available(dir).unwrap();
    for expect in ["small", "base", "wide"] {
        assert!(names.iter().any(|n| n == expect), "{names:?}");
    }
    for n in &names {
        let m = VariantMeta::load(dir, n).unwrap();
        assert_eq!(m.n_leaves, 1 << m.depth);
    }
}

#[test]
fn small_variant_matches_native_forest_everywhere() {
    let Some(dir) = artifacts() else { return };
    let data = datasets::load("iris").unwrap();
    let forest = ForestLearner::default()
        .trees(32)
        .max_depth(6)
        .seed(11)
        .fit(&data);
    let engine = XlaEngine::load(dir, "small").unwrap();
    let packed = PackedForest::pack(&forest, &engine.meta).unwrap();

    // run the entire dataset through fixed-size batches
    let m = engine.meta.clone();
    let mut checked = 0usize;
    for chunk in (0..data.n_rows()).collect::<Vec<_>>().chunks(m.batch) {
        let mut rows = RowMatrixBuf::with_capacity(data.n_features(), chunk.len());
        for &i in chunk {
            rows.push_row(data.row(i)).unwrap();
        }
        let preds = engine.classify_rows(rows.as_matrix(), &packed).unwrap();
        for (&i, &p) in chunk.iter().zip(&preds) {
            assert_eq!(p, forest.predict(data.row(i)), "row {i}");
            checked += 1;
        }
    }
    assert_eq!(checked, data.n_rows());
}

#[test]
fn votes_match_packed_reference_and_forest() {
    let Some(dir) = artifacts() else { return };
    let data = datasets::load("iris").unwrap();
    let forest = ForestLearner::default()
        .trees(32)
        .max_depth(6)
        .seed(4)
        .fit(&data);
    let engine = XlaEngine::load(dir, "small").unwrap();
    let m = engine.meta.clone();
    let packed = PackedForest::pack(&forest, &m).unwrap();
    let mut x = vec![0f32; m.batch * m.features];
    for b in 0..m.batch {
        let row = data.row(b * 4);
        x[b * m.features..b * m.features + row.len()].copy_from_slice(row);
    }
    let (votes, preds) = engine.run(&x, &packed).unwrap();
    assert_eq!(votes.len(), m.batch * m.classes);
    for b in 0..m.batch {
        let row = &x[b * m.features..b * m.features + 4];
        // XLA votes == pure-Rust packed reference == native forest votes
        let ref_votes = packed.eval_row(row, m.depth, m.classes);
        let xla_votes: Vec<u32> = votes[b * m.classes..(b + 1) * m.classes]
            .iter()
            .map(|&v| v as u32)
            .collect();
        assert_eq!(xla_votes, ref_votes, "batch row {b}");
        let native = forest.votes(row);
        assert_eq!(&xla_votes[..native.len()], &native[..], "batch row {b}");
        // pred is the argmax with lowest-index ties
        let argmax = xla_votes
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .unwrap()
            .0;
        assert_eq!(preds[b] as usize, argmax, "batch row {b}");
    }
}

#[test]
fn base_variant_with_replication() {
    let Some(dir) = artifacts() else { return };
    let data = datasets::load("breast-cancer").unwrap();
    // base: 128 tree slots; 32 trees -> 4x replication; F=16 >= 9, C=8 >= 2
    let forest = ForestLearner::default()
        .trees(32)
        .max_depth(8)
        .seed(21)
        .fit(&data);
    let engine = XlaEngine::load(dir, "base").unwrap();
    let packed = PackedForest::pack(&forest, &engine.meta).unwrap();
    assert_eq!(packed.replication, 4);
    let mut rows = RowMatrixBuf::with_capacity(data.n_features(), engine.meta.batch);
    for i in 0..engine.meta.batch {
        rows.push_row(data.row(i * 2)).unwrap();
    }
    let preds = engine.classify_rows(rows.as_matrix(), &packed).unwrap();
    for (row, &p) in rows.as_matrix().iter().zip(&preds) {
        assert_eq!(p, forest.predict(row));
    }
}

#[test]
fn engine_rejects_shape_violations() {
    let Some(dir) = artifacts() else { return };
    let data = datasets::load("iris").unwrap();
    let forest = ForestLearner::default()
        .trees(32)
        .max_depth(6)
        .seed(0)
        .fit(&data);
    let engine = XlaEngine::load(dir, "small").unwrap();
    let packed = PackedForest::pack(&forest, &engine.meta).unwrap();
    // wrong flat input size
    assert!(engine.run(&[0.0; 7], &packed).is_err());
    // too many rows
    let cells = vec![0f32; 4 * (engine.meta.batch + 1)];
    let rows = forest_add::batch::RowMatrix::new(&cells, 4).unwrap();
    assert!(engine.classify_rows(rows, &packed).is_err());
    // rows wider than the artifact
    let cells = vec![0f32; engine.meta.features + 1];
    let rows = forest_add::batch::RowMatrix::new(&cells, engine.meta.features + 1).unwrap();
    assert!(engine.classify_rows(rows, &packed).is_err());
    // unknown variant
    assert!(XlaEngine::load(dir, "huge").is_err());
}
