//! Reproduce the paper's running example (Figs. 1–5): a tiny Random Forest
//! on Iris and the Graphviz renderings of its aggregation stages —
//! class-word DD, class-vector DD, majority-vote DD, and the `*` variant
//! after unsatisfiable-path elimination.
//!
//! Run: `cargo run --release --example export_diagrams`
//! Then: `dot -Tpng figures/fig4_majority.dot -o fig4.png` (if graphviz is
//! installed) — the .dot files are plain text either way.

use forest_add::Result;
use forest_add::compile::{Abstraction, CompileOptions, ForestCompiler};
use forest_add::data::datasets;
use forest_add::forest::ForestLearner;

fn main() -> Result<()> {
    let data = datasets::load("iris")?;
    // The paper's running example uses a 3-tree forest (Fig. 1).
    let forest = ForestLearner::default()
        .trees(3)
        .max_depth(3)
        .seed(2)
        .fit(&data);
    let out = std::path::Path::new("figures");
    std::fs::create_dir_all(out)?;

    let stages: [(&str, Abstraction, bool); 4] = [
        ("fig2_word", Abstraction::Word, false),
        ("fig3_vector", Abstraction::Vector, false),
        ("fig4_majority", Abstraction::Majority, false),
        ("fig5_majority_star", Abstraction::Majority, true),
    ];
    for (name, abstraction, unsat) in stages {
        let dd = ForestCompiler::new(CompileOptions {
            abstraction,
            unsat_elim: unsat,
            ..Default::default()
        })
        .compile(&forest)?;
        let path = out.join(format!("{name}.dot"));
        std::fs::write(&path, dd.to_dot())?;
        println!(
            "{:<28} {} nodes -> {}",
            dd.label(),
            dd.size().total(),
            path.display()
        );
        // every stage stays semantically equivalent to the forest
        assert_eq!(dd.agreement(&forest, &data), 1.0);
    }
    println!("\nAll diagrams agree with the original forest on all 150 records.");
    Ok(())
}
