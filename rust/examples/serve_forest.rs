//! End-to-end serving driver — the system-level validation example.
//!
//! Boots the full three-layer stack in one process:
//!   L3 Rust coordinator (HTTP front-end, router, dynamic batcher)
//!   + the compiled `DD*` diagram (the paper's contribution)
//!   + the XLA/PJRT tensorised-forest executable (L2 JAX + L1 Pallas,
//!     AOT-compiled by `make artifacts`)
//!
//! then replays the Iris dataset as concurrent HTTP traffic against every
//! backend and reports latency/throughput plus cross-backend agreement.
//!
//! Run: `make artifacts && cargo run --release --example serve_forest`
//! The measured numbers are recorded in EXPERIMENTS.md §Serving.

use forest_add::Result;
use forest_add::data::datasets;
use forest_add::serve::config::ServeConfig;
use forest_add::serve::http::http_request;
use forest_add::serve::{server, BackendKind};
use forest_add::util::json::{self, Json};
use forest_add::util::table::Table;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

const CLIENT_THREADS: usize = 4;
const PASSES_PER_CLIENT: usize = 3;

fn main() -> Result<()> {
    // `small` artifact variant: 32 trees, depth 6, 8 features, 4 classes —
    // iris (4 features, 3 classes) fits after padding.
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        dataset: "iris".into(),
        trees: 32,
        max_depth: 6,
        seed: 7,
        variant: "small".into(),
        ..Default::default()
    };
    let handle = server::start(&cfg)?;
    let addr = handle.addr.to_string();
    println!("serving on http://{addr} (xla loaded: {})\n", handle.router.has_xla());

    // -- health + model info -------------------------------------------------
    let (st, health) = http_request(&addr, "GET", "/healthz", None)?;
    assert_eq!(st, 200);
    assert_eq!(health.get("ok").and_then(Json::as_bool), Some(true));
    let (_, model) = http_request(&addr, "GET", "/model", None)?;
    println!("model: {}", model.to_string_compact());

    let data = datasets::load("iris")?;
    let mut backends = vec![BackendKind::Forest, BackendKind::Dd];
    if handle.router.has_xla() {
        backends.push(BackendKind::Xla);
    }

    // -- agreement across backends (single requests) -------------------------
    let mut reference: Vec<u32> = Vec::new();
    for &backend in &backends {
        let mut preds = Vec::new();
        for i in 0..data.n_rows() {
            let body = json::obj(vec![
                (
                    "features",
                    Json::Arr(data.row(i).iter().map(|&v| json::num(v as f64)).collect()),
                ),
                ("backend", json::s(backend.name())),
            ]);
            let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body))?;
            assert_eq!(st, 200, "{resp:?}");
            preds.push(resp.get_i64("class").unwrap() as u32);
        }
        if reference.is_empty() {
            reference = preds.clone();
        }
        let agree = preds
            .iter()
            .zip(&reference)
            .filter(|(a, b)| a == b)
            .count();
        println!(
            "agreement {} vs {}: {}/{}",
            backend.name(),
            backends[0].name(),
            agree,
            data.n_rows()
        );
        assert_eq!(agree, data.n_rows(), "backends must agree — same semantics");
    }

    // -- concurrent load per backend -----------------------------------------
    let mut t = Table::new(&[
        "backend", "requests", "errors", "throughput (req/s)", "mean latency", "p99 latency",
    ]);
    for &backend in &backends {
        let counter = Arc::new(AtomicUsize::new(0));
        let errors = Arc::new(AtomicUsize::new(0));
        let lat_us = Arc::new(std::sync::Mutex::new(Vec::<u64>::new()));
        let start = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..CLIENT_THREADS {
                let addr = addr.clone();
                let data = &data;
                let counter = counter.clone();
                let errors = errors.clone();
                let lat_us = lat_us.clone();
                scope.spawn(move || {
                    let mut local = Vec::new();
                    for pass in 0..PASSES_PER_CLIENT {
                        for i in (c + pass..data.n_rows()).step_by(CLIENT_THREADS) {
                            let body = json::obj(vec![
                                (
                                    "features",
                                    Json::Arr(
                                        data.row(i)
                                            .iter()
                                            .map(|&v| json::num(v as f64))
                                            .collect(),
                                    ),
                                ),
                                ("backend", json::s(backend.name())),
                            ]);
                            let t0 = Instant::now();
                            match http_request(&addr, "POST", "/classify", Some(&body)) {
                                Ok((200, _)) => {
                                    local.push(t0.elapsed().as_micros() as u64);
                                    counter.fetch_add(1, Ordering::Relaxed);
                                }
                                _ => {
                                    errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                    lat_us.lock().unwrap().extend(local);
                });
            }
        });
        let elapsed = start.elapsed().as_secs_f64();
        let n = counter.load(Ordering::Relaxed);
        let mut lats = lat_us.lock().unwrap().clone();
        lats.sort_unstable();
        let mean = lats.iter().sum::<u64>() as f64 / lats.len().max(1) as f64;
        let p99 = lats
            .get((lats.len() as f64 * 0.99) as usize)
            .copied()
            .unwrap_or(0);
        t.row(vec![
            backend.name().to_string(),
            n.to_string(),
            errors.load(Ordering::Relaxed).to_string(),
            format!("{:.0}", n as f64 / elapsed),
            format!("{:.0} us", mean),
            format!("{p99} us"),
        ]);
    }
    println!("\n{}", t.to_text());

    // -- batched endpoint (the XLA fast path) ---------------------------------
    if handle.router.has_xla() {
        let rows: Vec<Json> = (0..16)
            .map(|i| Json::Arr(data.row(i * 9).iter().map(|&v| json::num(v as f64)).collect()))
            .collect();
        let body = json::obj(vec![("rows", Json::Arr(rows)), ("backend", json::s("xla"))]);
        let t0 = Instant::now();
        let (st, resp) = http_request(&addr, "POST", "/classify_batch", Some(&body))?;
        assert_eq!(st, 200, "{resp:?}");
        println!(
            "batched xla: 16 rows in {:.2?} -> {}",
            t0.elapsed(),
            resp.get("labels").unwrap().to_string_compact()
        );
    }

    // -- server-side metrics ---------------------------------------------------
    let (_, metrics) = http_request(&addr, "GET", "/metrics", None)?;
    println!("\nserver metrics: {}", metrics.to_string_pretty());
    handle.stop();
    println!("server stopped cleanly");
    Ok(())
}
