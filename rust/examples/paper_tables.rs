//! Regenerate the paper's Table 1 (classification steps) and Table 2
//! (structure sizes) across all six datasets at a configurable forest size.
//!
//! Run: `cargo run --release --example paper_tables` (defaults to 1,000
//! trees for a quick pass; `FOREST_ADD_BENCH_TABLE_TREES=10000` reproduces
//! the paper's setting — the full benches live in `cargo bench`).

use forest_add::Result;
use forest_add::bench_support::{table_row_budgeted, BenchEnv};
use forest_add::data::datasets;
use forest_add::util::table::{fmt_reduction, fmt_thousands, Table};

fn main() -> Result<()> {
    let trees = std::env::var("FOREST_ADD_BENCH_TABLE_TREES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);
    let _ = BenchEnv::load();
    println!("forests of size {trees} (paper: 10,000; raise via FOREST_ADD_BENCH_TABLE_TREES)\n");

    let mut t1 = Table::new(&["Dataset", "Random Forest", "Final DD", "reduction"]);
    let mut t2 = Table::new(&["Dataset", "Random Forest", "Final DD", "reduction"]);
    for name in datasets::names() {
        let data = datasets::load(name)?;
        eprintln!("[{name}] training + compiling …");
        let (forest, dd, reached) = table_row_budgeted(
            &data,
            trees,
            42,
            std::time::Duration::from_secs(120),
        );
        let forest = forest.prefix(reached);
        let rf_steps = forest.mean_steps(&data);
        let dd_steps = dd.mean_steps(&data);
        t1.row(vec![
            name.to_string(),
            fmt_thousands(rf_steps, 2),
            fmt_thousands(dd_steps, 2),
            fmt_reduction(rf_steps, dd_steps),
        ]);
        t2.row(vec![
            name.to_string(),
            fmt_thousands(forest.n_nodes() as f64, 0),
            fmt_thousands(dd.size().total() as f64, 0),
            fmt_reduction(forest.n_nodes() as f64, dd.size().total() as f64),
        ]);
    }
    println!("Table 1 — mean classification steps (forest size {trees})");
    print!("{}", t1.to_text());
    println!("\nTable 2 — structure sizes in nodes (forest size {trees})");
    print!("{}", t2.to_text());
    Ok(())
}
