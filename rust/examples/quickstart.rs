//! Quickstart: train a Random Forest, compile it into a single decision
//! diagram, serve both through one backend-polymorphic API, then freeze
//! the diagram into an `fdd-v2` snapshot and reload it the way a serving
//! replica would — the paper's core claim plus the crate's unified
//! `Engine` in sixty lines.
//!
//! Run: `cargo run --release --example quickstart`

use forest_add::classifier::{self, BackendKind};
use forest_add::engine::Engine;
use forest_add::serve::config::ServeConfig;
use forest_add::serve::http::{http_request, HttpClient};
use forest_add::util::json::{self, Json};
use forest_add::util::table::fmt_thousands;
use forest_add::Result;

fn main() -> Result<()> {
    // 1. One builder call: load a dataset, train the forest baseline,
    //    compile the paper's "Most frequent class DD*", and register both
    //    as the versioned model "default".
    let data = forest_add::data::datasets::load("iris")?;
    let engine = Engine::builder()
        .dataset(data.clone())
        .trees(150)
        .seed(7)
        .build()?;

    // 2. Every backend is a `Classifier` trait object in the registry;
    //    inspect them through the same lens the serving router uses.
    let version = engine.registry().get(None)?;
    println!(
        "model {} serves {} backends:",
        version.id,
        version.slots().len()
    );
    let mut steps = Vec::new();
    for slot in version.slots() {
        let info = slot.classifier.info();
        let mean = classifier::mean_steps(slot.classifier.as_ref(), &data)?;
        println!(
            "  {:<10} {:<28} {:>8} nodes  mean steps {}",
            info.backend.name(),
            info.label,
            fmt_thousands(info.size_nodes as f64, 0),
            mean.map(|s| fmt_thousands(s, 2))
                .unwrap_or_else(|| "n/a".into()),
        );
        steps.push(mean);
    }

    // 3. Same answers, orders of magnitude fewer steps.
    let (_, rf) = engine.registry().resolve(None, Some(BackendKind::Forest))?;
    let (_, dd) = engine.registry().resolve(None, Some(BackendKind::Dd))?;
    let agree = classifier::agreement(rf.classifier.as_ref(), dd.classifier.as_ref(), &data)?;
    assert_eq!(agree, 1.0, "semantics preserved");
    if let (Some(Some(rf_steps)), Some(Some(dd_steps))) = (steps.first(), steps.get(1)) {
        println!(
            "semantic agreement {agree}: forest {} vs diagram {} steps ({:.0}x faster)",
            fmt_thousands(*rf_steps, 2),
            fmt_thousands(*dd_steps, 2),
            rf_steps / dd_steps
        );
    }

    // 4. Classify a fresh measurement on the default backend (the DD),
    //    then pin the baseline backend explicitly — identical answer.
    let sample = vec![6.1f32, 2.9, 4.7, 1.4];
    let class = engine.classify(None, None, &sample)?;
    let baseline = engine.classify(None, Some(BackendKind::Forest), &sample)?;
    assert_eq!(class, baseline);
    println!("sample {sample:?} -> {}", version.label_of(class));

    // 5. Batches are one flat zero-copy matrix end to end: the whole
    //    dataset classifies as a single `RowMatrix` (sharded across cores
    //    when large), bit-identical to the single-row walks above.
    let batch = engine.classify_batch(None, None, data.matrix())?;
    assert_eq!(batch.len(), data.n_rows());
    assert_eq!(batch[0], engine.classify(None, None, data.row(0))?);
    println!("batched {} rows through one flat matrix", batch.len());

    // 6. Compile once, serve everywhere: export the engine's frozen
    //    backend as an `fdd-v2` snapshot, then register it on a fresh
    //    engine the way a serving replica does at startup. On 64-bit
    //    unix the artifact is mmap'd and its 64-byte-aligned sections
    //    back the runtime arrays in place — zero copies, zero per-node
    //    allocations, microsecond boot; hot memory per decision node is
    //    one 6-byte walk record plus two child words. Bit-identical
    //    answers either way.
    //    (CLI: `forest-add freeze` / `inspect` / `serve --snapshot`.)
    let snapshot = std::env::temp_dir().join("quickstart-iris.fdd");
    let snapshot = snapshot.to_str().expect("utf-8 temp path").to_string();
    engine.save_snapshot(None, &snapshot)?;
    let replica = Engine::new();
    replica.register_snapshot("iris", &snapshot)?;
    let from_snapshot = replica.classify(Some("iris"), None, &sample)?;
    assert_eq!(from_snapshot, class);
    println!(
        "snapshot replica agrees: {} (reloaded from {snapshot}, mmap boot: {})",
        version.label_of(from_snapshot),
        forest_add::runtime::mmap::enabled(),
    );
    let _ = std::fs::remove_file(&snapshot);

    // 7. Rapid evaluation, squared: the frozen sweeps route 4–8 parked
    //    rows per decision node through explicit SIMD kernels, chosen
    //    once at startup by runtime feature detection (SSE2/AVX2 on
    //    x86-64, NEON on aarch64, portable scalar elsewhere — kill
    //    switch: `FOREST_ADD_NO_SIMD=1` or `serve --no-simd`). Freeze
    //    can additionally pack feature columns by test frequency and
    //    quantise thresholds to f16 (halving the hot plane; refused if
    //    lossy) — every combination is bit-identical to the scalar
    //    single-row walk. (CLI: `freeze --pack-features --quantize-f16`,
    //    benched as the `frozen-scalar`/`frozen-simd`/`frozen-f16`
    //    series of `forest-add bench`.)
    let kernel = forest_add::runtime::simd::kernel();
    let dd = forest_add::compile::ForestCompiler::new(
        forest_add::compile::CompileOptions::default(),
    )
    .compile(
        &forest_add::forest::ForestLearner::default()
            .trees(50)
            .seed(7)
            .fit(&data),
    )?;
    let optimised = dd.freeze_with(forest_add::frozen::FreezeOpts {
        pack_features: true,
        quantize_f16: true,
    })?;
    assert_eq!(
        optimised.classify_batch(data.matrix()),
        dd.freeze().classify_batch(data.matrix()),
        "layout transforms never change answers"
    );
    println!(
        "simd kernel '{}' ({} lanes); optimised freeze: f16 thresholds {}, packed columns {}",
        kernel.name(),
        forest_add::runtime::simd::LANES,
        optimised.thresh_quant() == forest_add::frozen::ThreshQuant::F16,
        optimised.packed_features(),
    );

    // 8. Fleets serve many models per process: pack every registered
    //    model into one `fab-v1` bundle and boot a replica's whole
    //    registry from it — one artifact, one mmap, every entry a
    //    zero-copy model behind its manifest name, registered in one
    //    atomic hot-swap. (CLI: `forest-add bundle pack` / `bundle ls` /
    //    `serve --bundle fleet.fab`.)
    let canary = forest_add::data::datasets::load("tic-tac-toe")?;
    engine.train_and_register(
        "canary",
        &canary,
        50,
        0,
        11,
        forest_add::compile::CompileOptions::default(),
    )?;
    let fab = std::env::temp_dir().join("quickstart-fleet.fab");
    let fab = fab.to_str().expect("utf-8 temp path").to_string();
    engine.save_bundle(&[], &fab)?; // empty slice = every model
    let fleet = Engine::new();
    let ids = fleet.register_bundle(&fab)?;
    println!(
        "bundle replica booted {} models from {fab}: {}",
        ids.len(),
        ids.iter()
            .map(|id| id.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    );
    let from_bundle = fleet.classify(Some("default"), None, &sample)?;
    assert_eq!(from_bundle, class, "bundle entries stay bit-identical");
    let canary_class = fleet.classify(Some("canary"), None, canary.row(0))?;
    println!(
        "per-request model routing: canary row 0 -> class {canary_class}"
    );
    let _ = std::fs::remove_file(&fab);

    // 9. Serving: two interchangeable socket front-ends drive the same
    //    endpoint layer — the sync thread-per-connection pool and the
    //    epoll/kqueue evented loop (`serve --io sync|evented`, auto
    //    picks evented wherever a poller exists). Keep-alive, binary row
    //    frames, and `429` + `Retry-After` under overload come with
    //    either; responses are bit-identical across the two. Boot one
    //    and round-trip a classification over real HTTP.
    let serving = forest_add::serve::server::start(&ServeConfig {
        addr: "127.0.0.1:0".into(),
        dataset: "iris".into(),
        trees: 32,
        max_depth: 6,
        seed: 7,
        enable_xla: false,
        ..Default::default()
    })?;
    let addr = serving.addr.to_string();
    let body = json::obj(vec![(
        "features",
        Json::Arr(sample.iter().map(|&v| json::num(v as f64)).collect()),
    )]);
    let (st, resp) = http_request(&addr, "POST", "/classify", Some(&body))?;
    assert_eq!(st, 200);
    let (_, metrics) = http_request(&addr, "GET", "/metrics", None)?;
    println!(
        "served over the {} front-end: backend {} -> {}",
        metrics.get_str("io_mode").unwrap_or("?"),
        resp.get_str("backend").unwrap_or("?"),
        resp.get_str("label").unwrap_or("?"),
    );

    // 10. Observability: every response echoes an `X-Request-Id` (yours or
    //    a generated one), `"trace": true` returns the per-stage timing
    //    breakdown inline, the last traces sit in `/debug/trace`, and
    //    `/metrics?format=prometheus` renders every series for a scraper.
    //    (CLI: `serve --log-level debug --log-json`.)
    let mut client = HttpClient::connect(&addr)?;
    let traced = json::obj(vec![
        (
            "features",
            Json::Arr(sample.iter().map(|&v| json::num(v as f64)).collect()),
        ),
        ("trace", Json::Bool(true)),
    ]);
    let (st, headers, body) = client.request_raw_with_headers(
        "POST",
        "/classify",
        "application/json",
        &[("X-Request-Id", "00000000deadbeef")],
        traced.to_string_compact().as_bytes(),
    )?;
    assert_eq!(st, 200);
    let echoed = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("x-request-id"))
        .map(|(_, v)| v.as_str())
        .unwrap_or("?");
    let traced_resp = Json::parse(std::str::from_utf8(&body).expect("utf-8 body"))?;
    let eval_us = traced_resp
        .get("trace")
        .and_then(|t| t.get("stages"))
        .and_then(|s| s.get_i64("eval"))
        .unwrap_or(0);
    let (_, ring) = client.get("/debug/trace?n=4")?;
    let (st, _, prom) =
        client.request_raw("GET", "/metrics?format=prometheus", "application/json", &[])?;
    assert_eq!(st, 200);
    println!(
        "traced request {echoed}: eval {eval_us} µs, {} traces in the ring, \
         {} Prometheus series lines",
        ring.get("traces").and_then(|t| t.as_arr()).map_or(0, |a| a.len()),
        std::str::from_utf8(&prom)
            .map(|t| t.lines().filter(|l| !l.starts_with('#')).count())
            .unwrap_or(0),
    );
    // 11. Fault tolerance: every eval runs behind panic quarantine and a
    //     per-model×backend circuit breaker, and the backends are
    //     bit-identical — so failures degrade into rerouting, not wrong
    //     answers. Arm the deterministic injection harness so every
    //     frozen eval panics (`serve --fault eval_shard_panic:1:7` from
    //     the CLI): requests still answer 200 via the dd backend
    //     (announced with `X-Served-By`), three failures open the frozen
    //     breaker, and `/readyz` goes red so balancers drain the replica
    //     while `/healthz` keeps it alive. A cooldown later, one
    //     successful half-open probe re-closes the breaker.
    forest_add::runtime::fault::arm("eval_shard_panic:1:7").expect("valid fault spec");
    let frozen_req = json::obj(vec![
        (
            "features",
            Json::Arr(sample.iter().map(|&v| json::num(v as f64)).collect()),
        ),
        ("backend", json::s("frozen")),
    ]);
    let mut served_by = String::from("?");
    for _ in 0..3 {
        let mut c = HttpClient::connect(&addr)?;
        let (st, headers, _) = c.request_raw(
            "POST",
            "/classify",
            "application/json",
            frozen_req.to_string_compact().as_bytes(),
        )?;
        assert_eq!(st, 200, "a quarantined panic degrades, never fails");
        served_by = headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("x-served-by"))
            .map(|(_, v)| v.clone())
            .unwrap_or(served_by);
    }
    forest_add::runtime::fault::disarm_all();
    let (ready_st, ready) = http_request(&addr, "GET", "/readyz", None)?;
    assert_eq!(ready_st, 503, "an open breaker fails readiness");
    println!(
        "injected frozen panics: served by '{served_by}' instead, \
         readyz {ready_st} with open breakers {}",
        ready
            .get("open_breakers")
            .map(Json::to_string_compact)
            .unwrap_or_default(),
    );
    serving.stop();
    Ok(())
}
