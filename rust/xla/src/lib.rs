//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The build environment has no network access and no prebuilt
//! `xla_extension`, so the real bindings cannot be compiled here. This
//! crate mirrors the subset of the `xla` API that `forest_add::runtime`
//! uses; every entry point that would touch PJRT returns an
//! "unavailable" error instead. The serving layer already treats XLA
//! startup failures as a clean fallback to the native DD backend, so a
//! binary built against this stub serves correctly — just without the
//! tensorised batch path.
//!
//! On a machine with the real bindings, point the `xla` path dependency
//! in `rust/Cargo.toml` at them; no `forest_add` source changes needed.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA runtime is not linked into this build (offline `xla` stub); \
         use the native forest/dd backends or rebuild against the real bindings"
    )))
}

/// Host literal (tensor value). The stub carries no data.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Copy the elements out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO-text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded PJRT executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub — this is the single
    /// gate every runtime path passes through first.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform name (diagnostics).
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_runtime_gate_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_tuple().is_err());
        assert!(lit.to_vec::<i32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
