"""L2 model: variant contracts, shapes, and end-to-end (small) execution."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import VARIANTS, example_specs, forest_classify
from compile.kernels.ref import forest_predict_np

from .test_kernel import make_forest


def test_variant_invariants():
    names = set()
    for spec in VARIANTS:
        assert spec.name not in names
        names.add(spec.name)
        assert spec.trees % spec.block_trees == 0
        assert spec.n_nodes == 2**spec.depth - 1
        assert spec.n_leaves == 2**spec.depth
        # Fits comfortably in a 16 MiB VMEM budget with double-buffer headroom.
        assert spec.meta()["vmem_block_bytes"] < 8 * 2**20


@pytest.mark.parametrize("spec", VARIANTS, ids=lambda s: s.name)
def test_variant_output_shapes(spec):
    """jax.eval_shape: verify the full graph's output contract without running it."""
    votes, pred = jax.eval_shape(
        lambda *a: forest_classify(*a, spec=spec), *example_specs(spec)
    )
    assert votes.shape == (spec.batch, spec.classes) and votes.dtype == jnp.int32
    assert pred.shape == (spec.batch,) and pred.dtype == jnp.int32


def test_small_variant_end_to_end():
    spec = next(v for v in VARIANTS if v.name == "small")
    rng = np.random.default_rng(3)
    x, feat, thr, leaf = make_forest(
        rng,
        batch=spec.batch,
        trees=spec.trees,
        depth=spec.depth,
        features=spec.features,
        classes=spec.classes,
    )
    votes, pred = forest_classify(x, feat, thr, leaf, spec=spec)
    want_votes, want_pred = forest_predict_np(
        x, feat, thr, leaf, depth=spec.depth, classes=spec.classes
    )
    np.testing.assert_array_equal(np.asarray(votes), want_votes)
    np.testing.assert_array_equal(np.asarray(pred), want_pred)


def test_meta_roundtrip_fields():
    for spec in VARIANTS:
        meta = spec.meta()
        for key in (
            "name",
            "batch",
            "trees",
            "depth",
            "features",
            "classes",
            "block_trees",
            "n_nodes",
            "n_leaves",
            "vmem_block_bytes",
        ):
            assert key in meta, key
