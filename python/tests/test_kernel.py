"""L1 correctness: Pallas kernel vs pure-jnp ref vs scalar numpy oracle.

This is the core correctness signal for the compiled serving artifact: the
hypothesis sweep walks shapes/dtypes and random forest tensors and requires
exact agreement (votes are integer counts — no tolerance needed; the float
threshold compares use identical f32 semantics in all three implementations).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.forest_eval import forest_votes_pallas, vmem_block_bytes
from compile.kernels.ref import (
    forest_predict_np,
    forest_predict_ref,
    forest_votes_np,
    forest_votes_ref,
)


def make_forest(rng, *, batch, trees, depth, features, classes, thr_lo=-2.0, thr_hi=2.0):
    """Random forest tensors in complete-tree layout + a random input batch."""
    n_nodes = 2**depth - 1
    n_leaves = 2**depth
    x = rng.uniform(-3.0, 3.0, size=(batch, features)).astype(np.float32)
    feat = rng.integers(0, features, size=(trees, n_nodes)).astype(np.int32)
    thr = rng.uniform(thr_lo, thr_hi, size=(trees, n_nodes)).astype(np.float32)
    leaf = rng.integers(0, classes, size=(trees, n_leaves)).astype(np.int32)
    return x, feat, thr, leaf


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    batch=st.integers(1, 8),
    depth=st.integers(1, 5),
    block_trees=st.integers(1, 4),
    n_blocks=st.integers(1, 4),
    features=st.integers(1, 6),
    classes=st.integers(2, 6),
)
def test_pallas_matches_oracles(seed, batch, depth, block_trees, n_blocks, features, classes):
    trees = block_trees * n_blocks
    rng = np.random.default_rng(seed)
    x, feat, thr, leaf = make_forest(
        rng, batch=batch, trees=trees, depth=depth, features=features, classes=classes
    )
    got = np.asarray(
        forest_votes_pallas(
            x, feat, thr, leaf, depth=depth, classes=classes, block_trees=block_trees
        )
    )
    want_jnp = np.asarray(forest_votes_ref(x, feat, thr, leaf, depth=depth, classes=classes))
    want_np = forest_votes_np(x, feat, thr, leaf, depth=depth, classes=classes)
    np.testing.assert_array_equal(want_jnp, want_np)
    np.testing.assert_array_equal(got, want_np)
    # Every tree casts exactly one vote per example.
    assert (got.sum(axis=1) == trees).all()


def test_single_tree_hand_computed():
    """depth-1 stump: x[0] < 0.5 -> class 1 else class 2."""
    x = np.array([[0.0], [1.0], [0.5]], dtype=np.float32)  # 0.5 is NOT < 0.5 -> right
    feat = np.zeros((1, 1), dtype=np.int32)
    thr = np.full((1, 1), 0.5, dtype=np.float32)
    leaf = np.array([[1, 2]], dtype=np.int32)
    votes = np.asarray(
        forest_votes_pallas(x, feat, thr, leaf, depth=1, classes=3, block_trees=1)
    )
    np.testing.assert_array_equal(votes, [[0, 1, 0], [0, 0, 1], [0, 0, 1]])


def test_padding_inf_threshold_routes_left():
    """Dummy padding nodes (thr=+inf) must always route left — this is the
    contract the Rust packer relies on to pad shallow trees."""
    x = np.array([[1e30, -1e30]], dtype=np.float32)
    feat = np.zeros((1, 3), dtype=np.int32)
    thr = np.array([[np.inf, np.inf, np.inf]], dtype=np.float32)
    leaf = np.array([[7, 0, 0, 0]], dtype=np.int32)
    votes = np.asarray(forest_votes_pallas(x, feat, thr, leaf, depth=2, classes=8, block_trees=1))
    assert votes[0, 7] == 1 and votes.sum() == 1


def test_boundary_equal_goes_right():
    """x == thr takes the right child (predicate is strict `<`)."""
    x = np.array([[2.45]], dtype=np.float32)
    feat = np.zeros((1, 1), dtype=np.int32)
    thr = np.array([[2.45]], dtype=np.float32)
    leaf = np.array([[0, 1]], dtype=np.int32)
    votes = np.asarray(forest_votes_pallas(x, feat, thr, leaf, depth=1, classes=2, block_trees=1))
    np.testing.assert_array_equal(votes, [[0, 1]])


def test_block_trees_must_divide():
    rng = np.random.default_rng(0)
    x, feat, thr, leaf = make_forest(rng, batch=2, trees=6, depth=2, features=2, classes=2)
    with pytest.raises(ValueError, match="must divide"):
        forest_votes_pallas(x, feat, thr, leaf, depth=2, classes=2, block_trees=4)


def test_layout_shape_validation():
    rng = np.random.default_rng(0)
    x, feat, thr, leaf = make_forest(rng, batch=2, trees=2, depth=3, features=2, classes=2)
    with pytest.raises(ValueError, match="complete-tree"):
        forest_votes_pallas(x, feat, thr, leaf, depth=2, classes=2, block_trees=1)


def test_predict_tie_breaks_to_lowest_class():
    """Two trees voting class 2 and class 0 -> tie -> predict class 0."""
    x = np.zeros((1, 1), dtype=np.float32)
    feat = np.zeros((2, 1), dtype=np.int32)
    thr = np.full((2, 1), np.inf, dtype=np.float32)  # both go left
    leaf = np.array([[2, 0], [0, 0]], dtype=np.int32)
    votes, pred = forest_predict_ref(x, feat, thr, leaf, depth=1, classes=3)
    votes_np, pred_np = forest_predict_np(x, feat, thr, leaf, depth=1, classes=3)
    np.testing.assert_array_equal(np.asarray(votes), votes_np)
    assert int(pred[0]) == 0 == int(pred_np[0])


def test_vmem_block_model_monotone():
    """Footprint model grows with every dimension (sanity for §Perf sizing)."""
    base = dict(batch=64, features=16, depth=8, block_trees=16, classes=8)
    b0 = vmem_block_bytes(**base)
    for key in base:
        grown = dict(base)
        grown[key] = base[key] * 2
        assert vmem_block_bytes(**grown) > b0, key


def test_deterministic_across_calls():
    rng = np.random.default_rng(42)
    x, feat, thr, leaf = make_forest(rng, batch=4, trees=8, depth=3, features=3, classes=3)
    a = np.asarray(forest_votes_pallas(x, feat, thr, leaf, depth=3, classes=3, block_trees=4))
    b = np.asarray(forest_votes_pallas(x, feat, thr, leaf, depth=3, classes=3, block_trees=4))
    np.testing.assert_array_equal(a, b)


def test_block_size_invariance():
    """Vote totals must not depend on the VMEM tiling choice."""
    rng = np.random.default_rng(7)
    x, feat, thr, leaf = make_forest(rng, batch=3, trees=12, depth=3, features=4, classes=5)
    ref = None
    for bt in (1, 2, 3, 4, 6, 12):
        got = np.asarray(forest_votes_pallas(x, feat, thr, leaf, depth=3, classes=5, block_trees=bt))
        if ref is None:
            ref = got
        np.testing.assert_array_equal(got, ref)
