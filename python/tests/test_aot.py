"""AOT bridge: HLO-text emission and metadata sidecars.

Only the `small` variant is lowered here to keep the suite fast; `make
artifacts` lowers all variants and the Rust integration tests compile them
through the actual PJRT client.
"""

from __future__ import annotations

import json
import os

from compile import aot
from compile.model import VARIANTS


def test_lower_small_variant_to_hlo_text():
    spec = next(v for v in VARIANTS if v.name == "small")
    hlo = aot.lower_to_hlo_text(spec)
    # HLO text, not a serialized proto (xla_extension 0.5.1 interop contract).
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # The majority-vote argmax must have been fused into the same module.
    assert hlo.count("ENTRY") == 1


def test_emit_variant_writes_artifacts(tmp_path):
    spec = next(v for v in VARIANTS if v.name == "small")
    meta = aot.emit_variant(spec, str(tmp_path))
    hlo_path = tmp_path / meta["hlo_file"]
    meta_path = tmp_path / f"forest_{spec.name}.meta.json"
    assert hlo_path.exists() and meta_path.exists()
    on_disk = json.loads(meta_path.read_text())
    assert on_disk["trees"] == spec.trees
    assert on_disk["hlo_chars"] == len(hlo_path.read_text())


def test_main_emits_index(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--variant", "small"])
    assert rc == 0
    index = json.loads((tmp_path / "index.json").read_text())
    assert [v["name"] for v in index["variants"]] == ["small"]
    assert os.path.exists(tmp_path / "forest_small.hlo.txt")
