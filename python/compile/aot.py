"""AOT bridge: lower the L2 forest model to HLO *text* for the Rust runtime.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 (behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs, per variant in ``model.VARIANTS``:

    artifacts/forest_<name>.hlo.txt   — the compiled-from text by Rust/PJRT
    artifacts/forest_<name>.meta.json — shapes the Rust packer must honour
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import VARIANTS, VariantSpec, example_specs, forest_classify


def lower_to_hlo_text(spec: VariantSpec) -> str:
    """Lower one variant to HLO text (tupled outputs for ``to_tuple``)."""

    def fn(x, feat, thr, leaf):
        return forest_classify(x, feat, thr, leaf, spec=spec)

    lowered = jax.jit(fn).lower(*example_specs(spec))
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit_variant(spec: VariantSpec, out_dir: str) -> dict:
    hlo = lower_to_hlo_text(spec)
    hlo_path = os.path.join(out_dir, f"forest_{spec.name}.hlo.txt")
    meta_path = os.path.join(out_dir, f"forest_{spec.name}.meta.json")
    with open(hlo_path, "w") as f:
        f.write(hlo)
    meta = spec.meta()
    meta["hlo_file"] = os.path.basename(hlo_path)
    meta["hlo_chars"] = len(hlo)
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
        f.write("\n")
    return meta


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    parser.add_argument(
        "--variant",
        action="append",
        choices=[v.name for v in VARIANTS],
        help="lower only the named variant(s); default: all",
    )
    args = parser.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = set(args.variant) if args.variant else {v.name for v in VARIANTS}
    index = []
    for spec in VARIANTS:
        if spec.name not in wanted:
            continue
        meta = emit_variant(spec, args.out_dir)
        index.append(meta)
        print(
            f"[aot] {spec.name}: B={spec.batch} T={spec.trees} D={spec.depth} "
            f"F={spec.features} C={spec.classes} -> {meta['hlo_file']} "
            f"({meta['hlo_chars']} chars, VMEM/block {meta['vmem_block_bytes']} B)"
        )
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump({"variants": index}, f, indent=2, sort_keys=True)
        f.write("\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
