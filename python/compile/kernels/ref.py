"""Pure-jnp (and pure-numpy) oracles for the tensorised forest evaluator.

The serving-path artifact evaluates a *complete-tree layout* forest:

  - ``feat[T, N] int32``  — feature index tested at each internal node,
  - ``thr[T, N] float32`` — threshold; the predicate is ``x[f] < thr``,
  - ``leaf[T, L] int32``  — class label at each leaf,

with ``N = 2**depth - 1`` internal slots and ``L = 2**depth`` leaf slots per
tree. Traversal is level-synchronous: every cursor advances exactly ``depth``
levels (shallow trees are padded by the Rust packer with always-left dummy
nodes, ``thr = +inf``, that replicate the leaf class below them).

These references are the correctness oracle for the Pallas kernel
(``forest_eval.py``): ``forest_votes_ref`` is vectorised jnp, and
``forest_votes_np`` is a deliberately scalar numpy walk used by the pytest /
hypothesis suite as an independent second opinion.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "forest_votes_ref",
    "forest_predict_ref",
    "forest_votes_np",
    "forest_predict_np",
]


def forest_votes_ref(x, feat, thr, leaf, *, depth: int, classes: int):
    """Vectorised jnp reference: per-class vote counts ``[B, C] int32``.

    Semantics: each tree ``t`` routes example ``b`` left when
    ``x[b, feat[t, node]] < thr[t, node]`` and right otherwise, for exactly
    ``depth`` levels; the reached leaf's class receives one vote.
    """
    x = jnp.asarray(x, jnp.float32)
    feat = jnp.asarray(feat, jnp.int32)
    thr = jnp.asarray(thr, jnp.float32)
    leaf = jnp.asarray(leaf, jnp.int32)
    batch = x.shape[0]
    trees = feat.shape[0]

    # Cursor over *global* complete-tree node ids: children of i are 2i+1, 2i+2.
    idx = jnp.zeros((trees, batch), dtype=jnp.int32)
    cols = jnp.arange(batch, dtype=jnp.int32)[None, :]
    for _ in range(depth):
        f = jnp.take_along_axis(feat, idx, axis=1)  # [T, B]
        t = jnp.take_along_axis(thr, idx, axis=1)  # [T, B]
        xv = x[cols, f]  # [T, B] — x[b, f[t, b]]
        right = (xv >= t).astype(jnp.int32)
        idx = 2 * idx + 1 + right
    leaf_idx = idx - (2**depth - 1)
    cls = jnp.take_along_axis(leaf, leaf_idx, axis=1)  # [T, B]
    onehot = (cls[:, :, None] == jnp.arange(classes, dtype=jnp.int32)).astype(
        jnp.int32
    )  # [T, B, C]
    return onehot.sum(axis=0)  # [B, C]


def forest_predict_ref(x, feat, thr, leaf, *, depth: int, classes: int):
    """jnp reference returning ``(votes[B, C] int32, pred[B] int32)``.

    Ties break toward the lowest class index (``jnp.argmax`` convention),
    matching the Rust coordinator's majority-vote terminal abstraction.
    """
    votes = forest_votes_ref(x, feat, thr, leaf, depth=depth, classes=classes)
    pred = jnp.argmax(votes, axis=1).astype(jnp.int32)
    return votes, pred


def forest_votes_np(x, feat, thr, leaf, *, depth: int, classes: int):
    """Scalar numpy oracle — one explicit root-to-leaf walk per (tree, example)."""
    x = np.asarray(x, np.float32)
    feat = np.asarray(feat, np.int32)
    thr = np.asarray(thr, np.float32)
    leaf = np.asarray(leaf, np.int32)
    batch, trees = x.shape[0], feat.shape[0]
    votes = np.zeros((batch, classes), dtype=np.int32)
    for b in range(batch):
        for t in range(trees):
            idx = 0
            for _ in range(depth):
                f = feat[t, idx]
                idx = 2 * idx + 1 + (0 if x[b, f] < thr[t, idx] else 1)
            votes[b, leaf[t, idx - (2**depth - 1)]] += 1
    return votes


def forest_predict_np(x, feat, thr, leaf, *, depth: int, classes: int):
    """Scalar numpy oracle returning ``(votes, pred)`` with first-max ties."""
    votes = forest_votes_np(x, feat, thr, leaf, depth=depth, classes=classes)
    return votes, votes.argmax(axis=1).astype(np.int32)
