"""L1 Pallas kernel: batched complete-tree forest evaluation.

Hardware adaptation (paper is CPU/JVM; see DESIGN.md §Hardware-Adaptation):

  * The grid runs over *tree blocks*. Each grid step keeps one block of node
    tensors (``feat/thr[T_blk, N]``, ``leaf[T_blk, L]``) plus the full input
    batch ``x[B, F]`` resident in VMEM, expressed with ``BlockSpec`` so the
    HBM→VMEM schedule (and double-buffering of the next tree block) is
    Mosaic's to pipeline.
  * Traversal is level-synchronous — all ``T_blk × B`` cursors advance one
    level per step via gather + compare + select — so there is no
    data-dependent control flow, only dense VPU work.
  * Vote accumulation is fused: the one-hot class sum of each tree block is
    added into the single ``votes[B, C]`` output ref (the grid is sequential,
    so read-modify-write accumulation across steps is sound).

``interpret=True`` is mandatory in this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are verified
against ``ref.py`` by ``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["forest_votes_pallas", "vmem_block_bytes"]


def _forest_kernel(x_ref, feat_ref, thr_ref, leaf_ref, votes_ref, *, depth: int, classes: int):
    """One grid step: evaluate a block of trees on the whole batch."""
    x = x_ref[...]  # [B, F] f32
    feat = feat_ref[...]  # [Tb, N] i32
    thr = thr_ref[...]  # [Tb, N] f32
    leaf = leaf_ref[...]  # [Tb, L] i32
    batch = x.shape[0]
    t_blk = feat.shape[0]

    idx = jnp.zeros((t_blk, batch), dtype=jnp.int32)
    cols = jnp.arange(batch, dtype=jnp.int32)[None, :]
    # Static unroll over levels: `depth` is a compile-time constant, so the
    # lowered HLO is a straight-line chain of gathers/compares (no scan
    # bookkeeping for the short depths used here).
    for _ in range(depth):
        f = jnp.take_along_axis(feat, idx, axis=1)  # [Tb, B]
        t = jnp.take_along_axis(thr, idx, axis=1)  # [Tb, B]
        xv = x[cols, f]  # [Tb, B]
        right = (xv >= t).astype(jnp.int32)
        idx = 2 * idx + 1 + right

    leaf_idx = idx - (2**depth - 1)
    cls = jnp.take_along_axis(leaf, leaf_idx, axis=1)  # [Tb, B]
    onehot = (cls[:, :, None] == jnp.arange(classes, dtype=jnp.int32)).astype(jnp.int32)
    block_votes = onehot.sum(axis=0)  # [B, C]

    # Sequential-grid accumulation: zero once, then add each tree block.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        votes_ref[...] = jnp.zeros_like(votes_ref)

    votes_ref[...] += block_votes


def forest_votes_pallas(x, feat, thr, leaf, *, depth: int, classes: int, block_trees: int):
    """Per-class vote counts ``[B, C] int32`` via the Pallas kernel.

    ``block_trees`` must divide the tree count; it is the VMEM tile size over
    trees (see ``vmem_block_bytes`` for the footprint model).
    """
    batch, features = x.shape
    trees, n_nodes = feat.shape
    n_leaves = leaf.shape[1]
    if trees % block_trees != 0:
        raise ValueError(f"block_trees={block_trees} must divide trees={trees}")
    if n_nodes != 2**depth - 1 or n_leaves != 2**depth:
        raise ValueError(
            f"complete-tree layout requires N=2^depth-1, L=2^depth; got "
            f"N={n_nodes}, L={n_leaves}, depth={depth}"
        )

    grid = (trees // block_trees,)
    kernel = functools.partial(_forest_kernel, depth=depth, classes=classes)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch, features), lambda i: (0, 0)),  # x: whole batch
            pl.BlockSpec((block_trees, n_nodes), lambda i: (i, 0)),
            pl.BlockSpec((block_trees, n_nodes), lambda i: (i, 0)),
            pl.BlockSpec((block_trees, n_leaves), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((batch, classes), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, classes), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls (see module doc)
    )(
        jnp.asarray(x, jnp.float32),
        jnp.asarray(feat, jnp.int32),
        jnp.asarray(thr, jnp.float32),
        jnp.asarray(leaf, jnp.int32),
    )


def vmem_block_bytes(*, batch: int, features: int, depth: int, block_trees: int, classes: int) -> int:
    """VMEM bytes resident per grid step (the L1 footprint model used in
    DESIGN.md/EXPERIMENTS.md §Perf to size ``block_trees`` against the ~16 MiB
    TPU VMEM budget with headroom for double-buffering)."""
    n_nodes = 2**depth - 1
    n_leaves = 2**depth
    x_bytes = batch * features * 4
    node_bytes = block_trees * (n_nodes * (4 + 4) + n_leaves * 4)
    out_bytes = batch * classes * 4
    cursor_bytes = block_trees * batch * 4 * 3  # idx, gathered feat/thr working set
    return x_bytes + node_bytes + out_bytes + cursor_bytes
