"""L2 JAX model: the tensorised batched forest classifier.

This is the compute graph the Rust coordinator executes via PJRT on the
serving path. It composes the L1 Pallas kernel (vote accumulation over tree
blocks) with the final majority-vote argmax, so the whole request-path
computation lowers into a single HLO module:

    (x[B,F], feat[T,N], thr[T,N], leaf[T,L]) -> (votes[B,C], pred[B])

Variants (shape configurations) are declared in ``VARIANTS``; ``aot.py``
lowers each one to ``artifacts/forest_<name>.hlo.txt`` + a ``meta.json``
sidecar that the Rust runtime reads to pack forests into the tensor layout.
Python never runs at request time.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.forest_eval import forest_votes_pallas, vmem_block_bytes

__all__ = ["VariantSpec", "VARIANTS", "forest_classify", "example_specs"]


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """Static shape configuration for one compiled executable."""

    name: str
    batch: int
    trees: int
    depth: int
    features: int
    classes: int
    block_trees: int

    @property
    def n_nodes(self) -> int:
        return 2**self.depth - 1

    @property
    def n_leaves(self) -> int:
        return 2**self.depth

    def meta(self) -> dict:
        return {
            "name": self.name,
            "batch": self.batch,
            "trees": self.trees,
            "depth": self.depth,
            "features": self.features,
            "classes": self.classes,
            "block_trees": self.block_trees,
            "n_nodes": self.n_nodes,
            "n_leaves": self.n_leaves,
            "vmem_block_bytes": vmem_block_bytes(
                batch=self.batch,
                features=self.features,
                depth=self.depth,
                block_trees=self.block_trees,
                classes=self.classes,
            ),
        }


# One compiled executable per variant (the serving router picks by capacity).
VARIANTS = (
    VariantSpec("small", batch=16, trees=32, depth=6, features=8, classes=4, block_trees=8),
    VariantSpec("base", batch=64, trees=128, depth=8, features=16, classes=8, block_trees=16),
    VariantSpec("wide", batch=256, trees=128, depth=8, features=16, classes=8, block_trees=16),
)


def forest_classify(x, feat, thr, leaf, *, spec: VariantSpec):
    """Full request-path computation: votes via the Pallas kernel, then the
    majority vote (ties toward the lowest class index, matching the Rust
    ADD majority-vote abstraction)."""
    votes = forest_votes_pallas(
        x,
        feat,
        thr,
        leaf,
        depth=spec.depth,
        classes=spec.classes,
        block_trees=spec.block_trees,
    )
    pred = jnp.argmax(votes, axis=1).astype(jnp.int32)
    return votes, pred


def example_specs(spec: VariantSpec):
    """``jax.ShapeDtypeStruct`` arguments for ``jax.jit(...).lower``."""
    return (
        jax.ShapeDtypeStruct((spec.batch, spec.features), jnp.float32),
        jax.ShapeDtypeStruct((spec.trees, spec.n_nodes), jnp.int32),
        jax.ShapeDtypeStruct((spec.trees, spec.n_nodes), jnp.float32),
        jax.ShapeDtypeStruct((spec.trees, spec.n_leaves), jnp.int32),
    )
