//! Quickstart: train a Random Forest, aggregate it into a single decision
//! diagram, and compare classification cost — the paper's core claim in
//! thirty lines of API.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use forest_add::compile::{CompileOptions, ForestCompiler};
use forest_add::data::datasets;
use forest_add::forest::ForestLearner;
use forest_add::util::table::fmt_thousands;

fn main() -> Result<()> {
    // 1. Load a dataset and train a forest (the paper's baseline).
    let data = datasets::load("iris")?;
    let forest = ForestLearner::default().trees(150).seed(7).fit(&data);
    println!(
        "forest: {} trees, {} nodes, training accuracy {:.4}",
        forest.n_trees(),
        forest.n_nodes(),
        forest.accuracy(&data)
    );

    // 2. Compile it into the paper's "Most frequent class DD*": class-vector
    //    aggregation, majority vote at compile time, unsatisfiable-path
    //    elimination after every tree.
    let dd = ForestCompiler::new(CompileOptions::default()).compile(&forest)?;
    println!(
        "compiled {}: {} nodes in {:.2?} ({} reductions)",
        dd.label(),
        dd.size().total(),
        dd.stats.elapsed,
        dd.stats.reduces
    );

    // 3. Same answers, orders of magnitude fewer steps.
    assert_eq!(dd.agreement(&forest, &data), 1.0, "semantics preserved");
    let rf_steps = forest.mean_steps(&data);
    let dd_steps = dd.mean_steps(&data);
    println!(
        "mean steps/classification: forest {} vs diagram {} ({:.0}x)",
        fmt_thousands(rf_steps, 2),
        fmt_thousands(dd_steps, 2),
        rf_steps / dd_steps
    );
    println!(
        "structure size: forest {} nodes vs diagram {} nodes ({:.1}% reduction)",
        fmt_thousands(forest.n_nodes() as f64, 0),
        fmt_thousands(dd.size().total() as f64, 0),
        100.0 * (1.0 - dd.size().total() as f64 / forest.n_nodes() as f64)
    );

    // 4. Classify a fresh measurement.
    let sample = [6.1f32, 2.9, 4.7, 1.4];
    let class = dd.classify(&sample);
    println!(
        "sample {sample:?} -> {}",
        dd.schema.classes[class as usize]
    );
    Ok(())
}
