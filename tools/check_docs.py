#!/usr/bin/env python3
"""Docs cross-link checker (CI `docs` job; runnable locally from anywhere).

Three invariants keep the documentation layer from rotting:

1. The documented surface exists: README.md and docs/{ARCHITECTURE,
   FORMAT,HTTP}.md are present and non-trivial.
2. Every relative markdown link in those files resolves to a real file
   in the repository (external http(s) links are not fetched).
3. The source ↔ docs cross-references hold both ways: the format
   modules and the fixture generator cite docs/FORMAT.md, the HTTP
   layer cites docs/HTTP.md, the crate root cites docs/ARCHITECTURE.md
   — and every `SEC_*` section id declared in snapshot.rs appears in
   FORMAT.md's section tables, so a new section cannot land
   undocumented.

Exit code 0 = all good; 1 = problems (listed on stderr).
"""

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REQUIRED_DOCS = [
    "README.md",
    "docs/ARCHITECTURE.md",
    "docs/FORMAT.md",
    "docs/HTTP.md",
]

# source file -> docs path it must mention
SOURCE_REFS = {
    "rust/src/lib.rs": "docs/ARCHITECTURE.md",
    "rust/src/frozen/snapshot.rs": "docs/FORMAT.md",
    "rust/src/frozen/bundle.rs": "docs/FORMAT.md",
    "rust/tests/fixtures/gen_tiny_fdd.py": "docs/FORMAT.md",
    "rust/src/serve/http.rs": "docs/HTTP.md",
}

MIN_DOC_BYTES = 500

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SEC_RE = re.compile(r"const SEC_\w+: u32 = (\d+);")

problems = []


def check_exists():
    for rel in REQUIRED_DOCS:
        path = os.path.join(ROOT, rel)
        if not os.path.isfile(path):
            problems.append(f"missing required doc: {rel}")
        elif os.path.getsize(path) < MIN_DOC_BYTES:
            problems.append(f"suspiciously small doc (<{MIN_DOC_BYTES}B): {rel}")


def check_links():
    for rel in REQUIRED_DOCS:
        path = os.path.join(ROOT, rel)
        if not os.path.isfile(path):
            continue
        base = os.path.dirname(path)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue  # pure in-page anchor
            resolved = os.path.normpath(os.path.join(base, target))
            if not os.path.exists(resolved):
                problems.append(f"{rel}: broken relative link -> {target}")


def check_source_refs():
    for src, doc in SOURCE_REFS.items():
        path = os.path.join(ROOT, src)
        if not os.path.isfile(path):
            problems.append(f"missing source file: {src}")
            continue
        with open(path, encoding="utf-8") as f:
            if doc not in f.read():
                problems.append(f"{src}: does not reference {doc}")


def check_section_ids():
    snap = os.path.join(ROOT, "rust/src/frozen/snapshot.rs")
    fmt = os.path.join(ROOT, "docs/FORMAT.md")
    if not (os.path.isfile(snap) and os.path.isfile(fmt)):
        return  # already reported above
    with open(snap, encoding="utf-8") as f:
        ids = sorted({int(m) for m in SEC_RE.findall(f.read())})
    if not ids:
        problems.append("snapshot.rs: no SEC_* section ids found (regex drift?)")
        return
    with open(fmt, encoding="utf-8") as f:
        fmt_text = f.read()
    for sec in ids:
        # FORMAT.md's section tables list each id as a `| N ` table cell
        if not re.search(rf"^\|\s*{sec}\s+\|", fmt_text, re.MULTILINE):
            problems.append(
                f"docs/FORMAT.md: section id {sec} (declared in snapshot.rs) "
                "missing from the section tables"
            )


def main():
    check_exists()
    check_links()
    check_source_refs()
    check_section_ids()
    if problems:
        for p in problems:
            print(f"check_docs: {p}", file=sys.stderr)
        return 1
    print(f"check_docs: OK ({len(REQUIRED_DOCS)} docs, cross-links intact)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
